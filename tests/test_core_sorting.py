"""Unit + property tests for §4.2.1 sort-by-destination."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis — deterministic stub
    from _hypothesis_stub import given, settings, st

from repro.core import sorting as S

from helpers import make_rays


@given(
    st.integers(1, 64).flatmap(
        lambda n: st.tuples(
            st.just(n),
            st.lists(st.integers(-1, 7), min_size=n, max_size=n),
            st.integers(0, n),
        )
    )
)
@settings(max_examples=40, deadline=None)
def test_pack_keys_sort_matches_stable_argsort(args):
    n, dests, count = args
    cap = 64
    dest = jnp.zeros(cap, jnp.int32).at[: len(dests)].set(jnp.array(dests, jnp.int32))
    R = 8
    keys = S.pack_keys(dest, jnp.int32(count), R)
    d_sorted, lanes = S.unpack_keys(jax.lax.sort(keys), cap, R)
    # oracle: stable argsort on the sanitized destination
    lane = np.arange(cap)
    valid = (lane < count) & (np.asarray(dest) >= 0) & (np.asarray(dest) < R)
    d = np.where(valid, np.asarray(dest), R)
    perm = np.argsort(d, kind="stable")
    np.testing.assert_array_equal(np.asarray(d_sorted), d[perm])
    np.testing.assert_array_equal(np.asarray(lanes), perm)


@given(
    st.lists(st.integers(-2, 9), min_size=0, max_size=100),
    st.integers(0, 100),
)
@settings(max_examples=40, deadline=None)
def test_histogram_matches_numpy(dests, count):
    cap = 128
    R = 8
    dest = jnp.full((cap,), -1, jnp.int32).at[: len(dests)].set(jnp.array(dests, jnp.int32))
    h = np.asarray(S.destination_histogram(dest, jnp.int32(count), R))
    lane = np.arange(cap)
    d = np.asarray(dest)
    valid = (lane < count) & (d >= 0) & (d < R)
    expect = np.bincount(np.where(valid, d, R), minlength=R + 1)
    np.testing.assert_array_equal(h, expect)
    assert h.sum() == cap


@pytest.mark.parametrize("method", ["pack", "argsort"])
def test_sort_by_destination_full(method):
    cap, R, n = 64, 8, 40
    rays = make_rays(cap)
    rng = np.random.default_rng(0)
    dest = jnp.array(rng.integers(-1, R, cap), jnp.int32)
    items, d_sorted, counts = S.sort_by_destination(rays, dest, jnp.int32(n), R, method=method)
    d = np.asarray(dest)
    lane = np.arange(cap)
    valid = (lane < n) & (d >= 0)
    d_clean = np.where(valid, d, R)
    perm = np.argsort(d_clean, kind="stable")
    np.testing.assert_array_equal(np.asarray(d_sorted), d_clean[perm])
    # payload permuted identically (each ray read exactly once — §4.2.1)
    np.testing.assert_array_equal(np.asarray(items.pixel), np.asarray(rays.pixel)[perm])
    np.testing.assert_allclose(np.asarray(items.origin), np.asarray(rays.origin)[perm])
    np.testing.assert_array_equal(np.asarray(counts), np.bincount(d_clean, minlength=R + 1))


@given(st.lists(st.integers(0, 5), min_size=1, max_size=60))
@settings(max_examples=40, deadline=None)
def test_segment_bounds_match_histogram_offsets(dests):
    """The paper's boundary-detection formulation (§4.2.2 step 1) must agree
    with the histogram+cumsum formulation we actually use."""
    R = 6
    d_sorted = jnp.array(sorted(dests), jnp.int32)
    begin, end = S.segment_bounds_from_sorted(d_sorted, R)
    counts = np.bincount(dests, minlength=R)
    off = np.cumsum(counts) - counts
    np.testing.assert_array_equal(np.asarray(end) - np.asarray(begin), counts)
    np.testing.assert_array_equal(np.asarray(begin), off)


@given(
    st.lists(st.integers(-2, 9), min_size=0, max_size=100),
    st.integers(0, 100),
)
@settings(max_examples=40, deadline=None)
def test_destination_rank_matches_sort(dests, count):
    """The counting-sort plan is the sort's inverse image: item i must land at
    sorted position off[d_clean[i]] + rank[i], and the histogram must equal
    the sort path's — no keys, no sort, same placement."""
    cap = 128
    R = 8
    dest = jnp.full((cap,), -1, jnp.int32).at[: len(dests)].set(
        jnp.array(dests, jnp.int32)
    )
    d_clean, rank, hist = S.destination_rank(dest, jnp.int32(count), R)
    perm, d_sorted, counts = S.sort_permutation(dest, jnp.int32(count), R)
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(counts))
    off = np.concatenate([[0], np.cumsum(np.asarray(hist))[:-1]])
    pos = off[np.asarray(d_clean)] + np.asarray(rank)
    # scatter-to-pos inverts the sort permutation exactly
    inv = np.empty(cap, np.int64)
    inv[np.asarray(perm)] = np.arange(cap)
    np.testing.assert_array_equal(pos, inv)


@given(st.lists(st.integers(0, 5), min_size=1, max_size=60))
@settings(max_examples=40, deadline=None)
def test_segment_bounds_from_histogram_match_neighbor_compare(dests):
    """The O(R) histogram-derived bounds must agree with the paper's O(C)
    neighbor-compare boundary detection — the latter survives only as this
    cross-validation oracle; no exchange stage re-scans the sorted vector."""
    R = 6
    d_sorted = jnp.array(sorted(dests), jnp.int32)
    counts = jnp.array(np.bincount(dests, minlength=R), jnp.int32)
    begin_h, end_h = S.segment_bounds_from_histogram(counts)
    begin_s, end_s = S.segment_bounds_from_sorted(d_sorted, R)
    np.testing.assert_array_equal(np.asarray(begin_h), np.asarray(begin_s))
    np.testing.assert_array_equal(np.asarray(end_h), np.asarray(end_s))


def test_pack_keys_rejects_overflow():
    with pytest.raises(ValueError):
        S.pack_keys(jnp.zeros(1 << 26, jnp.int32), jnp.int32(0), 1 << 10)


# --------------------------------------------- hierarchical N-level key sort
@pytest.mark.parametrize(
    "level_sizes",
    [(2, 4), (4, 2), (1, 8), (8, 1), (2, 2, 2), (2, 1, 4), (1, 2, 4), (2, 2, 2, 1)],
)
@pytest.mark.parametrize("method", ["pack", "argsort"])
def test_hierarchical_sort_matches_flat_sort(level_sizes, method):
    """Global ranks are lexicographic in the tier digits (node-major in the
    2-level case), so the (d_0, …, d_{L-1}, slot) N-level key order must
    coincide with the flat (dest, slot) order — one sort serves both the flat
    and the N-stage exchange."""
    cap = 64
    R = int(np.prod(level_sizes))
    rng = np.random.default_rng(sum(level_sizes) * 10 + len(level_sizes))
    dest = jnp.array(rng.integers(-1, R + 1, cap), jnp.int32)
    count = jnp.int32(50)
    perm_h, cnt_tensor = S.sort_permutation_hierarchical(
        dest, count, level_sizes, method=method
    )
    perm_f, _d, counts_f = S.sort_permutation(dest, count, R, method="pack")
    np.testing.assert_array_equal(np.asarray(perm_h), np.asarray(perm_f))
    assert cnt_tensor.shape == level_sizes
    np.testing.assert_array_equal(
        np.asarray(cnt_tensor).reshape(-1), np.asarray(counts_f)[:R]
    )


@pytest.mark.parametrize("level_sizes", [(2, 4), (2, 2, 2), (2, 1, 4)])
@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_hierarchical_keys_roundtrip(level_sizes, data):
    cap = 64
    R = int(np.prod(level_sizes))
    dests = data.draw(st.lists(st.integers(-1, R), min_size=1, max_size=cap))
    count = data.draw(st.integers(0, cap))
    dest = jnp.zeros(cap, jnp.int32).at[: len(dests)].set(jnp.array(dests, jnp.int32))
    keys = S.pack_keys_hierarchical(dest, jnp.int32(count), level_sizes)
    digits, slot = S.unpack_keys_hierarchical(keys, cap, level_sizes)
    lane = np.arange(cap)
    d = np.asarray(dest)
    valid = (lane < count) & (d >= 0) & (d < R)
    want = d.copy()
    for t, a in reversed(list(enumerate(level_sizes))):
        if t == 0:
            np.testing.assert_array_equal(
                np.asarray(digits[0]), np.where(valid, want, level_sizes[0])
            )
        else:
            np.testing.assert_array_equal(
                np.asarray(digits[t]), np.where(valid, want % a, 0)
            )
            want = want // a
    np.testing.assert_array_equal(np.asarray(slot), lane)


def test_hierarchical_keys_reject_overflow():
    with pytest.raises(ValueError):
        S.pack_keys_hierarchical(
            jnp.zeros(1 << 26, jnp.int32), jnp.int32(0), (1 << 8, 4)
        )

"""Integration tests for forward_work (§4.2) across exchange backends."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.core import (
    DISCARD,
    ForwardConfig,
    WorkQueue,
    enqueue,
    forward_work,
    make_queue,
    rebalance,
    run_until_done,
)

from helpers import Ray, make_rays, ray_proto

R = 8
CAP = 64


def _emit_and_forward(cfg, dest_of):
    """Per-rank kernel: emit 10 rays with destinations dest_of(me, k)."""

    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index("data")
        n = 10
        k = jnp.arange(n)
        rays = Ray(
            origin=jnp.ones((n, 3)) * me,
            direction=jnp.zeros((n, 3)),
            tmin=k.astype(jnp.float32),
            pixel=(k + me * 100).astype(jnp.int32),
            integral=jnp.zeros(n),
        )
        dest = dest_of(me, k).astype(jnp.int32)
        q = enqueue(q, rays, dest, jnp.ones(n, bool))
        nq, total = forward_work(q, cfg)
        return nq.count[None], total, nq.items.pixel, nq.items.origin, nq.drops[None]

    return kernel


def _run(mesh8, cfg, dest_of):
    f = jax.jit(
        compat.shard_map(
            _emit_and_forward(cfg, dest_of),
            mesh=mesh8,
            in_specs=P("data"),
            out_specs=(P("data"), P(), P("data"), P("data"), P("data")),
        )
    )
    counts, total, pixels, origins, drops = f(jnp.arange(8.0))
    return (
        np.asarray(counts),
        int(total),
        np.asarray(pixels).reshape(R, CAP),
        np.asarray(origins).reshape(R, CAP, 3),
        np.asarray(drops),
    )


@pytest.mark.parametrize("exchange", ["padded", "onehot"])
@pytest.mark.parametrize("sort_method", ["pack", "argsort"])
def test_all_items_arrive_where_addressed(mesh8, exchange, sort_method):
    cfg = ForwardConfig("data", R, CAP, exchange=exchange, sort_method=sort_method)
    counts, total, pixels, origins, drops = _run(mesh8, cfg, lambda me, k: (me + k) % R)
    assert total == 80 and counts.sum() == 80 and drops.sum() == 0
    for r in range(R):
        # rank r receives one ray from each source s with k = (r - s) % 10… but
        # only k in [0,10) and dest==r ⇒ sources where (s + k) % R == r.
        got = sorted(pixels[r][: counts[r]].tolist())
        expect = sorted(
            s * 100 + k for s in range(R) for k in range(10) if (s + k) % R == r
        )
        assert got == expect, f"rank {r}: {got} != {expect}"
        # provenance: origin encodes the source rank
        srcs = origins[r][: counts[r], 0].astype(int)
        assert sorted(srcs.tolist()) == sorted(p // 100 for p in expect)


def test_padded_equals_onehot_bitwise(mesh8):
    kw = dict(sort_method="pack")
    c1 = ForwardConfig("data", R, CAP, exchange="padded", **kw)
    c2 = ForwardConfig("data", R, CAP, exchange="onehot", **kw)
    rng_dest = lambda me, k: (me * 3 + k * 7) % R
    a = _run(mesh8, c1, rng_dest)
    b = _run(mesh8, c2, rng_dest)
    np.testing.assert_array_equal(a[0], b[0])
    for r in range(R):  # valid prefixes identical (both stable); tails are garbage
        n = a[0][r]
        np.testing.assert_array_equal(a[2][r][:n], b[2][r][:n])


def test_self_send_identity(mesh8):
    """A rank forwarding to itself receives its own items in emit order."""
    cfg = ForwardConfig("data", R, CAP, exchange="padded")
    counts, total, pixels, origins, _ = _run(mesh8, cfg, lambda me, k: me * jnp.ones_like(k))
    assert total == 80
    for r in range(R):
        np.testing.assert_array_equal(pixels[r][:10], np.arange(10) + r * 100)


def test_empty_queues_forward_cleanly(mesh8):
    cfg = ForwardConfig("data", R, CAP, exchange="padded")
    counts, total, *_ = _run(mesh8, cfg, lambda me, k: 0 * k - 1)  # all discard
    assert total == 0 and counts.sum() == 0


def test_peer_capacity_overflow_drops_are_counted(mesh8):
    # everyone sends all 10 items to rank 0 with peer slots of 4
    cfg = ForwardConfig("data", R, CAP, peer_capacity=4, exchange="padded")
    counts, total, pixels, _, drops = _run(mesh8, cfg, lambda me, k: 0 * k)
    assert counts[0] == 32  # 8 sources × 4-slot clamp
    assert drops.sum() == 8 * 6  # 6 dropped per source
    assert total == 32


def test_receiver_capacity_overflow(mesh8):
    # capacity 64 < 80 incoming at rank 0 when everyone sends everything there
    cfg = ForwardConfig("data", R, CAP, peer_capacity=10, exchange="padded")
    counts, total, *_rest = _run(mesh8, cfg, lambda me, k: 0 * k)
    assert counts[0] == CAP
    assert total == CAP


def test_ragged_exchange_lowers_with_ragged_all_to_all(mesh8):
    """XLA:CPU cannot run ragged-all-to-all; assert the TPU production path
    lowers to the dedicated op (the MPI_Alltoallv analogue)."""
    if not compat.HAS_RAGGED_ALL_TO_ALL:
        pytest.skip("installed JAX has no lax.ragged_all_to_all")
    cfg = ForwardConfig("data", R, CAP, exchange="ragged")

    def k(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index("data")
        q = enqueue(
            q, make_rays(4), ((me + 1) % R) * jnp.ones(4, jnp.int32), jnp.ones(4, bool)
        )
        nq, _ = forward_work(q, cfg)
        return nq.items.tmin

    low = jax.jit(
        compat.shard_map(k, mesh=mesh8, in_specs=P("data"), out_specs=P("data"))
    ).lower(jnp.arange(8.0))
    assert "ragged_all_to_all" in low.as_text()


def test_multi_round_termination(mesh8):
    """Items hop rank→rank+1 five times then retire; the while_loop must run
    exactly 5 rounds and deposit every item (distributed termination §4.2.3)."""
    cfg = ForwardConfig("data", R, CAP, exchange="padded")

    def round_fn(q_in, acc, rnd):
        me = jax.lax.axis_index("data")
        out = make_queue(ray_proto(), CAP)
        lane = jnp.arange(CAP)
        valid = lane < q_in.count
        rays = q_in.items
        moved = Ray(
            origin=rays.origin,
            direction=rays.direction,
            tmin=rays.tmin + 1.0,
            pixel=rays.pixel,
            integral=rays.integral + 1.0,
        )
        keep = valid & (moved.integral < 5.0)
        dest = jnp.where(keep, (me + 1) % R, DISCARD).astype(jnp.int32)
        out = enqueue(out, moved, dest, valid)
        acc = acc + jnp.sum(jnp.where(valid & ~keep, moved.integral, 0.0))
        return out, acc

    def drive(_x):
        me = jax.lax.axis_index("data")
        q0 = make_queue(ray_proto(), CAP)
        q0 = enqueue(q0, make_rays(2), me * jnp.ones(2, jnp.int32), jnp.ones(2, bool))
        q, acc, rounds, done = run_until_done(round_fn, q0, jnp.zeros(()), cfg, max_rounds=32)
        return acc[None], rounds[None], done[None]

    f = jax.jit(
        compat.shard_map(drive, mesh=mesh8, in_specs=P("data"),
                         out_specs=(P("data"), P("data"), P("data")))
    )
    acc, rounds, done = f(jnp.arange(8.0))
    assert float(np.asarray(acc).sum()) == 8 * 2 * 5.0
    assert int(np.asarray(rounds)[0]) == 5
    # the clean exit: the global count hit zero, so the verdict is True
    assert bool(np.asarray(done).all())


def test_drops_not_double_counted_when_round_fn_threads_queue_drops(mesh8):
    """Drops contract of run_until_done: the driver owns the cumulative drop
    count, so a round_fn that copies its INPUT queue's ``drops`` into its
    output queue (natural when threading queue state) must not inflate the
    total — the driver hands round_fn a zero-drop view of the input queue.

    Construction: rank 0 sends 6 rays to rank 1 in the seed queue and in each
    of the first 3 loop rounds, with peer slots clamped at 2 — exactly 4
    sender-side drops per forwarding round, 16 total.  The round_fn
    deliberately carries ``q_in.drops`` into its output queue; with the old
    accounting the carried value re-entered the sum every round (a
    triangular overcount: 56 here)."""
    cfg = ForwardConfig("data", R, CAP, peer_capacity=2, exchange="padded")

    def emit_burst(out, me, gate):
        n = 6
        dest = jnp.where(gate, 1, DISCARD) * jnp.ones(n, jnp.int32)
        return enqueue(out, make_rays(n), dest.astype(jnp.int32), jnp.ones(n, bool))

    def round_fn(q_in, acc, rnd):
        me = jax.lax.axis_index("data")
        out = make_queue(ray_proto(), CAP)
        # thread the input queue's drops through — the driver must make
        # this a no-op, not a double count
        out = WorkQueue(items=out.items, dest=out.dest, count=out.count,
                        drops=q_in.drops)
        return emit_burst(out, me, (me == 0) & (rnd < 3)), acc

    def drive(_x):
        me = jax.lax.axis_index("data")
        q0 = emit_burst(make_queue(ray_proto(), CAP), me, me == 0)
        q, acc, rounds, _done = run_until_done(
            round_fn, q0, jnp.zeros(()), cfg, max_rounds=8
        )
        return q.drops[None], rounds[None]

    f = jax.jit(
        compat.shard_map(drive, mesh=mesh8, in_specs=P("data"),
                         out_specs=(P("data"), P("data")))
    )
    drops, _rounds = f(jnp.arange(8.0))
    # 4 forwarding rounds × (6 emitted − 2 delivered) = 16 — NOT the
    # carried-forward triangular sum the double count would produce
    assert int(np.asarray(drops).sum()) == 16, np.asarray(drops)


def test_max_rounds_cap_with_work_still_in_flight(mesh8):
    """ISSUE 5 satellite: a round_fn that never retires its items (perpetual
    ring forwarding) must hit the ``max_rounds`` bound with the in-flight
    work still VISIBLE — the returned queue carries a nonzero count (the
    items are parked, not lost) and the drop counter stays zero (a round cap
    is not a capacity overflow; inflating drops there would fake a §3.3
    clamp that never happened)."""
    cfg = ForwardConfig("data", R, CAP, exchange="padded")
    n = 5

    def round_fn(q_in, acc, rnd):
        me = jax.lax.axis_index("data")
        out = make_queue(ray_proto(), CAP)
        lane = jnp.arange(CAP)
        valid = lane < q_in.count
        dest = jnp.where(valid, (me + 1) % R, DISCARD).astype(jnp.int32)
        return enqueue(out, q_in.items, dest, valid), acc + q_in.count

    def drive(_x):
        me = jax.lax.axis_index("data")
        q0 = make_queue(ray_proto(), CAP)
        q0 = enqueue(q0, make_rays(n), me * jnp.ones(n, jnp.int32), jnp.ones(n, bool))
        q, acc, rounds, done = run_until_done(
            round_fn, q0, jnp.zeros((), jnp.int32), cfg, max_rounds=3
        )
        return q.count[None], q.drops[None], rounds[None], acc[None], done[None]

    f = jax.jit(
        compat.shard_map(
            drive, mesh=mesh8, in_specs=P("data"),
            out_specs=(P("data"), P("data"), P("data"), P("data"), P("data")),
        )
    )
    count, drops, rounds, acc, done = f(jnp.arange(8.0))
    assert int(np.asarray(rounds)[0]) == 3  # the cap, not termination
    # the truncated exit: work still in flight, so the verdict is False
    assert not bool(np.asarray(done).any())
    # every rank still holds its n items — in flight, reported, not dropped
    np.testing.assert_array_equal(np.asarray(count).reshape(-1), np.full(R, n))
    assert int(np.asarray(count).sum()) == R * n
    assert int(np.asarray(drops).sum()) == 0, "round cap must not inflate drops"
    # the loop really ran: 3 processed batches per rank rode the carry
    assert int(np.asarray(acc).sum()) == R * n * 3


def test_rebalance_equalizes_load(mesh8):
    cfg = ForwardConfig("data", R, CAP, exchange="padded")

    def bal(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index("data")
        n = jnp.where(me == 0, 40, jnp.where(me == 1, 8, 0))
        mask = jnp.arange(48) < n
        q = enqueue(q, make_rays(48), jnp.zeros(48, jnp.int32), mask)
        q = WorkQueue(
            items=q.items,
            dest=jnp.full((CAP,), DISCARD, jnp.int32),
            count=q.count,
            drops=q.drops,
        )
        nq, total = rebalance(q, cfg)
        return nq.count[None], total

    f = jax.jit(compat.shard_map(bal, mesh=mesh8, in_specs=P("data"), out_specs=(P("data"), P())))
    counts, total = f(jnp.arange(8.0))
    counts = np.asarray(counts)
    assert int(total) == 48
    assert counts.max() - counts.min() <= 1 or counts.max() <= int(np.ceil(48 / R))


def test_forward_on_joint_mesh_axes(mesh24):
    """Forwarding over a *tuple* of mesh axes (pod, data) — the multi-pod path."""
    cfg = ForwardConfig(("data", "model"), 8, CAP, exchange="padded")

    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index(("data", "model"))
        q = enqueue(
            q,
            make_rays(4),
            ((me + 3) % 8) * jnp.ones(4, jnp.int32),
            jnp.ones(4, bool),
        )
        nq, total = forward_work(q, cfg)
        return nq.count[None], total

    f = jax.jit(
        compat.shard_map(
            kernel,
            mesh=mesh24,
            in_specs=P(("data", "model")),
            out_specs=(P(("data", "model")), P()),
        )
    )
    counts, total = f(jnp.arange(8.0))
    assert int(total) == 32
    np.testing.assert_array_equal(np.asarray(counts), [4] * 8)


def test_queue_cycling_delivers_everything(mesh8):
    """§6.3's 'ray queue cycling' (Barney): R nearest-neighbour hops deliver
    the same items one forward_work round would — only the pattern differs."""
    from repro.core.cycling import deliver_by_cycling

    cfg = ForwardConfig("data", R, CAP, exchange="padded")

    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index("data")
        n = 6
        k = jnp.arange(n)
        rays = make_rays(n, pixel_base=int(0))
        rays = Ray(
            origin=rays.origin, direction=rays.direction, tmin=rays.tmin,
            pixel=(k + me * 100).astype(jnp.int32), integral=rays.integral,
        )
        q = enqueue(q, rays, ((me * 3 + k) % R).astype(jnp.int32), jnp.ones(n, bool))
        absorbed, total = deliver_by_cycling(q, cfg)
        return absorbed.count[None], total, absorbed.items.pixel

    f = jax.jit(compat.shard_map(kernel, mesh=mesh8, in_specs=P("data"),
                              out_specs=(P("data"), P(), P("data"))))
    counts, total, pixels = f(jnp.arange(8.0))
    assert int(total) == 8 * 6
    pixels = np.asarray(pixels).reshape(R, CAP)
    counts = np.asarray(counts)
    got = sorted(
        int(pixels[r, i]) for r in range(R) for i in range(counts[r])
    )
    expect = sorted(s * 100 + k for s in range(R) for k in range(6))
    assert got == expect

"""Golden-output tests for the roofline reporters (ISSUE 10, satellite 3).

``roofline/report.py`` renders dry-run artifacts into the EXPERIMENTS.md
tables and ``roofline/inspect.py`` parses compiled HLO into the collective
byte inventory.  Both are read by humans chasing regressions, so their
output is pinned EXACTLY here — a formatting drift is a real break for the
diffing workflow, not cosmetics.

The inspect goldens cover both HLO result spellings — the bare shape list
of unoptimized/StableHLO text and the parenthesized tuple form the
optimized CPU/TPU HLO uses (one component per participant) — and close the
loop against the collective-budget law: parsing the COMPILED padded round
must recover the same payload byte total the lowering-level budget tests
pin (``R * peer_capacity * WORDS * 4``).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import ForwardConfig, enqueue, forward_work, make_queue
from repro.core import types as T

from helpers import make_rays, ray_proto

# importing the inspector force-sets XLA_FLAGS for its CLI use; restore the
# suite's 8-device setting so subprocess-spawning tests are unaffected
_saved_flags = os.environ.get("XLA_FLAGS")
from repro.roofline import inspect as RI  # noqa: E402
from repro.roofline import report as RR  # noqa: E402

if _saved_flags is None:
    os.environ.pop("XLA_FLAGS", None)
else:
    os.environ["XLA_FLAGS"] = _saved_flags

R, CAP = 8, 64
WORDS = T.pack_spec(ray_proto()).total_words


# ------------------------------------------------------------ report.py
def _artifact(name, rec, root):
    (root / name).write_text(json.dumps(rec))


def _ok(arch, shape, step, t_comp, t_mem, t_coll, dominant, mem_bytes, uf,
        coll_breakdown=None, tag=""):
    return {
        "status": "ok", "arch": arch, "shape": shape, "step": step,
        "tag": tag,
        "roofline": {
            "t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
            "dominant": dominant, "coll_breakdown": coll_breakdown or {},
        },
        "memory": {"peak_bytes_per_device": mem_bytes},
        "useful_flops_ratio": uf,
    }


@pytest.fixture
def artifacts(tmp_path, monkeypatch):
    monkeypatch.setattr(RR, "ARTIFACTS", tmp_path)
    _artifact("a__pod1.json", _ok(
        "toy", "train_1k", 12, 1.5, 0.8, 0.2, "compute", 12.3e9, 0.55,
    ), tmp_path)
    _artifact("b__pod1.json", _ok(
        "toy", "train_4k", 3, 0.4, 0.9, 0.1, "memory", 30.0e9, 0.40,
    ), tmp_path)
    _artifact("c__pod1.json", _ok(
        "big", "train_8k", 7, 0.2, 0.3, 0.6, "collective", 64.0e9, 0.35,
        coll_breakdown={"all-gather": 0.2, "all-to-all": 0.4},
    ), tmp_path)
    _artifact("d__pod1.json", {
        "status": "skip", "arch": "huge", "shape": "train_32k",
        "tag": "", "reason": "needs 512 chips",
    }, tmp_path)
    _artifact("e__pod1.json", {
        "status": "error", "arch": "bad", "shape": "train_1k",
        "tag": "", "error": "OOM during layout assignment",
    }, tmp_path)
    # excluded: wrong mesh tag in the file name
    _artifact("f__pod2.json", _ok(
        "other", "x", 1, 1.0, 0.1, 0.1, "compute", 1e9, 0.9,
    ), tmp_path)
    # excluded: file name matches but the record carries a different tag
    _artifact("g__pod1.json", _ok(
        "other", "y", 1, 1.0, 0.1, 0.1, "compute", 1e9, 0.9, tag="probe",
    ), tmp_path)
    return tmp_path


def test_roofline_table_golden(artifacts):
    assert RR.roofline_table("pod1") == "\n".join([
        "| arch | shape | step | t_comp | t_mem | t_coll | bound | HBM/chip | useful_F | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
        "| toy | train_1k | 12 | 1.50s | 800.0ms | 200.0ms | **comp** | "
        "12.3GB | 0.55 | cf=1.00; near compute roofline |",
        "| toy | train_4k | 3 | 400.0ms | 900.0ms | 100.0ms | **memo** | "
        "30.0GB | 0.40 | cf=0.44; cut bytes: fused/banded attention, bf16 CE, less remat |",
        "| big | train_8k | 7 | 200.0ms | 300.0ms | 600.0ms | **coll** | "
        "64.0GB | 0.35 | cf=0.33; dominant coll=all-to-all: reshard/overlap or shrink TP |",
        "| huge | train_32k | skip | - | - | - | - | - | - | needs 512 chips |",
        "| bad | train_1k | ERR | - | - | - | - | - | - | OOM during layout assignment |",
    ])


def test_roofline_summary_golden(artifacts):
    # ok records only, sorted ascending by compute fraction
    assert RR.summary("pod1") == [
        ("big", "train_8k", 7, "collective", 0.333, 64.0),
        ("toy", "train_4k", 3, "memory", 0.444, 30.0),
        ("toy", "train_1k", 12, "compute", 1.0, 12.3),
    ]


def test_roofline_load_filters_mesh_and_tag(artifacts):
    assert [r["arch"] for r in RR.load("pod1")] == [
        "toy", "toy", "big", "huge", "bad"
    ]
    assert [r["arch"] for r in RR.load("pod2")] == ["other"]
    assert [r["shape"] for r in RR.load("pod1", tag="probe")] == []


def test_fmt_s_units():
    assert RR._fmt_s(None) == "-"
    assert RR._fmt_s(1.0) == "1.00s"
    assert RR._fmt_s(0.0125) == "12.5ms"


# ----------------------------------------------------------- inspect.py
_SYNTHETIC_HLO = "\n".join([
    # bare shape list (StableHLO / unoptimized spelling)
    "  %ag = f32[8,64]{1,0} all-gather(f32[1,64]{1,0} %p), dimensions={0}",
    "  %ag2 = f32[8,64]{1,0} all-gather(f32[1,64]{1,0} %q), dimensions={0}",
    # tuple form (optimized HLO): bytes summed over every component
    "  %all-to-all.5 = (u32[1,16,9]{2,1,0}, u32[1,16,9]{2,1,0}) "
    "all-to-all(u32[1,16,9]{2,1,0} %a, u32[1,16,9]{2,1,0} %b)",
    # async start variant is counted once
    "  %ar = bf16[1024]{0} all-reduce-start(bf16[1024]{0} %x), to_apply=%add",
    # a get-tuple-element naming an all-to-all is NOT a collective op
    "  %gte = u32[1,16,9]{2,1,0} get-tuple-element((u32[1,16,9]{2,1,0}, "
    "u32[1,16,9]{2,1,0}) %all-to-all.5), index=0",
])


def test_top_collectives_synthetic_golden():
    got = RI.top_collectives(_SYNTHETIC_HLO)
    by_kind = {kind: b for (kind, _shape), b in got}
    # two identical all-gathers aggregate: 2 * 8*64*4
    assert by_kind["all-gather"] == 2 * 8 * 64 * 4
    # tuple form sums both components: 2 * 1*16*9 * 4
    assert by_kind["all-to-all"] == 2 * 16 * 9 * 4
    assert by_kind["all-reduce"] == 1024 * 2
    # exactly three inventory rows — the gte line contributed nothing
    assert len(got) == 3


def _compile_padded_round(mesh8, cfg):
    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index("data")
        q = enqueue(
            q, make_rays(10), ((me + jnp.arange(10)) % R).astype(jnp.int32),
            jnp.ones(10, bool),
        )
        nq, total = forward_work(q, cfg)
        return nq.count[None], total, nq.items.tmin

    return jax.jit(
        compat.shard_map(
            kernel, mesh=mesh8, in_specs=P("data"),
            out_specs=(P("data"), P(), P("data")),
        )
    ).lower(jnp.arange(8.0)).compile()


def test_top_collectives_recovers_budget_law_from_compiled_hlo(mesh8):
    """End to end: the inspector, reading only the optimized HLO text of a
    compiled padded round, re-derives the wire budget the lowering-level
    tests pin — ONE payload all_to_all of ``R*S*W*4`` bytes and ONE count
    all_to_all of ``R*4`` bytes."""
    cfg = ForwardConfig("data", R, CAP, exchange="padded")
    compiled = _compile_padded_round(mesh8, cfg)
    got = RI.top_collectives(compiled.as_text())
    a2a = sorted(b for (kind, _s), b in got if kind == "all-to-all")
    assert a2a == [R * 4, R * cfg.peer_capacity * WORDS * 4]
    # the only other traffic is the scalar count reduction
    others = [(k, b) for (k, _s), b in got if k != "all-to-all"]
    assert all(b <= R * R * 4 for _k, b in others), others


def test_buffer_report_golden(mesh8):
    class _Mem:
        argument_size_in_bytes = 2.0e9
        output_size_in_bytes = 5.0e8
        temp_size_in_bytes = 0.0

    class _Compiled:
        def memory_analysis(self):
            return _Mem()

    assert RI.buffer_report(_Compiled()) == "args=2.00GB out=0.50GB temp=0.00GB"

    class _Broken:
        def memory_analysis(self):
            raise RuntimeError("unsupported on this backend")

    assert RI.buffer_report(_Broken()) == "unsupported on this backend"

    # the real compiled round is tiny — every term rounds to 0.00GB
    cfg = ForwardConfig("data", R, CAP, exchange="padded")
    compiled = _compile_padded_round(mesh8, cfg)
    assert RI.buffer_report(compiled) == "args=0.00GB out=0.00GB temp=0.00GB"

"""The bucket-scatter marshal (ISSUE 4): bit-exactness + drop accounting.

``ForwardConfig(marshal="scatter")`` must be *observationally identical* to
the sort path (and hence to the ``onehot`` oracle): same counts, same drops,
bit-exact placement — the scatter reproduces the §4.2.1 lexicographic stable
source order without ever sorting.  Property-tested on flat and 2/3-level
hierarchical meshes, including the hot-spot, the all-DISCARD round, and
sender/receiver capacity overflow; the Pallas ``bucket_scatter`` path is
pinned against the XLA path under the ``pallas_interpret`` CI toggle.

The drop-accounting regression: when ONE overflowing segment is clamped at
MULTIPLE hierarchy tiers, every dropped item must be counted exactly once —
asserted with exact per-stage-derivable numbers, not just conservation.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis — deterministic stub
    from _hypothesis_stub import given, settings, st
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import DISCARD, ForwardConfig, WorkQueue, forward_work, work_item

R, CAP = 8, 64
AXES3 = ("pod", "node", "device")


@work_item
@dataclasses.dataclass
class Item:
    val: jax.Array
    src: jax.Array


def _make_fn(mesh, cfg, axes="data"):
    def fwd(items_val, dest, counts):
        me = jax.lax.axis_index(axes)
        q = WorkQueue(
            items=Item(val=items_val, src=me * jnp.ones(CAP, jnp.int32)),
            dest=dest,
            count=counts[0],
            drops=jnp.zeros((), jnp.int32),
        )
        nq, total = forward_work(q, cfg)
        return nq.items.val, nq.items.src, nq.count[None], nq.drops[None], total

    return jax.jit(
        compat.shard_map(
            fwd, mesh=mesh,
            in_specs=(P(axes), P(axes), P(axes)),
            out_specs=(P(axes), P(axes), P(axes), P(axes), P()),
        )
    )


def _run_pair(fn_a, fn_b, counts, dest, val):
    """Counts, drops, termination total and valid-prefix placement must be
    bit-identical between the two configs (tails are garbage/zeros)."""
    args = (
        jnp.asarray(val).reshape(-1),
        jnp.asarray(dest).reshape(-1),
        jnp.asarray(counts),
    )
    a = [np.asarray(x) for x in fn_a(*args)]
    b = [np.asarray(x) for x in fn_b(*args)]
    np.testing.assert_array_equal(a[2], b[2], err_msg="per-rank receive counts")
    av, as_ = a[0].reshape(R, CAP), a[1].reshape(R, CAP)
    bv, bs = b[0].reshape(R, CAP), b[1].reshape(R, CAP)
    for r in range(R):
        n = int(a[2].reshape(-1)[r])
        np.testing.assert_array_equal(av[r][:n], bv[r][:n])
        np.testing.assert_array_equal(as_[r][:n], bs[r][:n])
    assert int(a[3].sum()) == int(b[3].sum()), "global drops"
    assert int(a[4]) == int(b[4]), "termination total"
    lane = np.arange(CAP)[None, :]
    emitted = int(((lane < counts[:, None]) & (dest >= 0) & (dest < R)).sum())
    assert int(a[2].sum()) + int(a[3].sum()) == emitted, "conservation"


# ----------------------------------------------------------- flat exchanges
@pytest.fixture(scope="module")
def flat_fns(mesh8):
    """Four flat configs on the 8-way mesh: scatter/sort at the DEFAULT
    (tight) peer slots pin the sender-clamp behaviour against each other;
    scatter at AMPLE slots (peer_capacity=CAP — the receiver clamp is then
    the only drop site, same as the oracle's) is pinned against onehot."""
    return (
        _make_fn(mesh8, ForwardConfig("data", R, CAP, exchange="padded", marshal="scatter")),
        _make_fn(mesh8, ForwardConfig("data", R, CAP, exchange="padded")),
        _make_fn(
            mesh8,
            ForwardConfig(
                "data", R, CAP, exchange="padded", marshal="scatter",
                peer_capacity=CAP,
            ),
        ),
        _make_fn(mesh8, ForwardConfig("data", R, CAP, exchange="onehot")),
    )


@given(data=st.data())
@settings(max_examples=15, deadline=None)
def test_flat_scatter_matches_sort_and_onehot(flat_fns, data):
    scatter, sort, scatter_ample, onehot = flat_fns
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(-1, R, (R, CAP)).astype(np.int32)  # incl. DISCARD lanes
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _run_pair(scatter, sort, counts, dest, val)
    _run_pair(scatter_ample, onehot, counts, dest, val)


def test_flat_scatter_hotspot(flat_fns):
    """Everyone floods rank 0 at full queue — receiver clamp fires."""
    scatter, sort, scatter_ample, onehot = flat_fns
    counts = np.full(R, CAP, np.int32)
    dest = np.zeros((R, CAP), np.int32)
    val = np.random.default_rng(1).normal(size=(R, CAP)).astype(np.float32)
    _run_pair(scatter, sort, counts, dest, val)
    _run_pair(scatter_ample, onehot, counts, dest, val)


def test_flat_scatter_all_discard(flat_fns):
    scatter, sort, *_ = flat_fns
    counts = np.full(R, CAP, np.int32)
    dest = np.full((R, CAP), DISCARD, np.int32)
    val = np.zeros((R, CAP), np.float32)
    _run_pair(scatter, sort, counts, dest, val)


def test_flat_scatter_sender_overflow(mesh8):
    """peer_capacity clamp: the scatter's rank >= S cut must drop exactly the
    rows the sort path's segment clamp drops — same items, same counts."""
    scatter = _make_fn(
        mesh8,
        ForwardConfig("data", R, CAP, exchange="padded", marshal="scatter", peer_capacity=3),
    )
    sort = _make_fn(
        mesh8, ForwardConfig("data", R, CAP, exchange="padded", peer_capacity=3)
    )
    rng = np.random.default_rng(5)
    counts = np.full(R, CAP, np.int32)
    dest = rng.integers(0, 3, (R, CAP)).astype(np.int32)  # 3 hot destinations
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _run_pair(scatter, sort, counts, dest, val)


@pytest.mark.parametrize("exchange", ["padded", "onehot"])
def test_flat_scatter_backend_self_consistency(mesh8, exchange):
    """scatter mode of each flat backend vs its own sort mode."""
    scatter = _make_fn(
        mesh8, ForwardConfig("data", R, CAP, exchange=exchange, marshal="scatter")
    )
    sort = _make_fn(mesh8, ForwardConfig("data", R, CAP, exchange=exchange))
    rng = np.random.default_rng(9)
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(-1, R, (R, CAP)).astype(np.int32)
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _run_pair(scatter, sort, counts, dest, val)


def test_ragged_scatter_lowers_with_one_ragged_collective(mesh8):
    """The ragged backend's scatter mode must still lower to the single
    ragged_all_to_all + one count all_gather (budget unchanged)."""
    if not compat.HAS_RAGGED_ALL_TO_ALL:
        pytest.skip("installed JAX has no lax.ragged_all_to_all")
    from repro.roofline.analysis import collective_ops

    cfg = ForwardConfig("data", R, CAP, exchange="ragged", marshal="scatter")
    fn = _make_fn(mesh8, cfg)
    txt = fn.lower(
        jnp.zeros(R * CAP), jnp.zeros(R * CAP, jnp.int32), jnp.zeros(R, jnp.int32)
    ).as_text()
    ops = collective_ops(txt)
    assert sum(1 for k, _ in ops if k == "ragged-all-to-all") == 1, ops
    assert sum(1 for k, _ in ops if k == "all-to-all") == 0, ops


# -------------------------------------------------- hierarchical exchanges
def _hier_cfg(level_sizes, ample, **kw):
    if ample:
        caps, mult = [], 1
        for a in reversed(level_sizes):
            caps.append(CAP * mult)
            mult *= a
        kw["level_capacities"] = tuple(reversed(caps))
    axes = AXES3 if len(level_sizes) == 3 else ("node", "device")
    return ForwardConfig(
        axes, R, CAP, exchange="hierarchical", level_sizes=level_sizes, **kw
    )


@pytest.fixture(scope="module")
def hier3_fns(mesh_pods222):
    """(scatter, sort, onehot) on the (2, 2, 2) mesh with ample stage caps."""
    return (
        _make_fn(mesh_pods222, _hier_cfg((2, 2, 2), True, marshal="scatter"), AXES3),
        _make_fn(mesh_pods222, _hier_cfg((2, 2, 2), True), AXES3),
        _make_fn(
            mesh_pods222, ForwardConfig(AXES3, R, CAP, exchange="onehot"), AXES3
        ),
    )


@given(data=st.data())
@settings(max_examples=12, deadline=None)
def test_3level_scatter_matches_sort_and_onehot(hier3_fns, data):
    scatter, sort, onehot = hier3_fns
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(-1, R, (R, CAP)).astype(np.int32)
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _run_pair(scatter, sort, counts, dest, val)
    _run_pair(scatter, onehot, counts, dest, val)


def test_3level_scatter_hotspot(hier3_fns):
    scatter, sort, onehot = hier3_fns
    counts = np.full(R, CAP, np.int32)
    dest = np.zeros((R, CAP), np.int32)
    val = np.random.default_rng(2).normal(size=(R, CAP)).astype(np.float32)
    _run_pair(scatter, sort, counts, dest, val)
    _run_pair(scatter, onehot, counts, dest, val)


def test_3level_scatter_all_discard(hier3_fns):
    scatter, sort, _ = hier3_fns
    counts = np.full(R, CAP, np.int32)
    dest = np.full((R, CAP), DISCARD, np.int32)
    _run_pair(scatter, sort, counts, dest, np.zeros((R, CAP), np.float32))


@given(data=st.data())
@settings(max_examples=8, deadline=None)
def test_2level_scatter_matches_sort_tight_caps(mesh_nodes24, data):
    """Default (tight) stage capacities under skew: both modes clamp the same
    sub-segments at the same tiers."""
    scatter = _make_fn(
        mesh_nodes24, _hier_cfg((2, 4), False, marshal="scatter"), ("node", "device")
    )
    sort = _make_fn(mesh_nodes24, _hier_cfg((2, 4), False), ("node", "device"))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(0, R, (R, CAP)).astype(np.int32)
    dest[::2] = 0  # heavy skew
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _run_pair(scatter, sort, counts, dest, val)


@pytest.mark.parametrize(
    "shape", [(1, 2, 4), (2, 1, 4), (2, 4, 1), (1, 1, 8)],
    ids=lambda s: "x".join(map(str, s)),
)
def test_3level_scatter_degenerate_axes(shape):
    """Extent-1 tiers anywhere: the scatter stage composition must follow the
    same skipped-stage structure as the sort path."""
    from repro.launch.mesh import make_pod_mesh

    mesh = make_pod_mesh(*shape)
    scatter = _make_fn(
        mesh, _hier_cfg(shape, True, marshal="scatter"), AXES3
    )
    sort = _make_fn(mesh, _hier_cfg(shape, True), AXES3)
    rng = np.random.default_rng(sum(shape))
    for hotspot in (False, True):
        counts = (
            np.full(R, CAP, np.int32)
            if hotspot
            else rng.integers(0, CAP + 1, R).astype(np.int32)
        )
        dest = (
            np.zeros((R, CAP), np.int32)
            if hotspot
            else rng.integers(0, R, (R, CAP)).astype(np.int32)
        )
        val = rng.normal(size=(R, CAP)).astype(np.float32)
        _run_pair(scatter, sort, counts, dest, val)


# ------------------------------------------------------------- Pallas path
@pytest.mark.pallas_interpret
@pytest.mark.parametrize("kind", ["flat", "hier3"])
def test_scatter_pallas_path_matches_xla_path(mesh8, mesh_pods222, kind):
    """use_pallas=True routes the plan through kernels/bucket_scatter and the
    payload pass through its scatter kernel — bit-exact with the XLA path."""
    if kind == "flat":
        mesh, axes = mesh8, "data"
        mk = lambda up: ForwardConfig(
            "data", R, CAP, exchange="padded", marshal="scatter", use_pallas=up
        )
    else:
        mesh, axes = mesh_pods222, AXES3
        mk = lambda up: _hier_cfg((2, 2, 2), True, marshal="scatter", use_pallas=up)
    fn_p = _make_fn(mesh, mk(True), axes)
    fn_x = _make_fn(mesh, mk(False), axes)
    rng = np.random.default_rng(13)
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(-1, R, (R, CAP)).astype(np.int32)
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _run_pair(fn_p, fn_x, counts, dest, val)


# ------------------------------------------------------------------ cycling
@pytest.mark.parametrize("use_pallas", [False, True], ids=["xla", "pallas"])
def test_cycling_scatter_delivers_everything(mesh8, use_pallas, request):
    """§6.3 cycling with the sort-free hop compaction delivers every item."""
    if use_pallas:
        request.applymarker(pytest.mark.pallas_interpret)
    from repro.core import enqueue, make_queue
    from repro.core.cycling import deliver_by_cycling

    cfg = ForwardConfig(
        "data", R, CAP, exchange="padded", marshal="scatter", use_pallas=use_pallas
    )

    def kernel(_x):
        proto = Item(val=jnp.zeros(()), src=jnp.zeros((), jnp.int32))
        q = make_queue(proto, CAP)
        me = jax.lax.axis_index("data")
        n = 6
        k = jnp.arange(n)
        items = Item(
            val=(k + me * 100).astype(jnp.float32),
            src=me * jnp.ones(n, jnp.int32),
        )
        q = enqueue(q, items, ((me * 3 + k) % R).astype(jnp.int32), jnp.ones(n, bool))
        absorbed, total = deliver_by_cycling(q, cfg)
        return absorbed.count[None], total, absorbed.items.val

    f = jax.jit(
        compat.shard_map(
            kernel, mesh=mesh8, in_specs=P("data"),
            out_specs=(P("data"), P(), P("data")),
        )
    )
    counts, total, vals = f(jnp.arange(8.0))
    counts = np.asarray(counts)
    vals = np.asarray(vals).reshape(R, CAP)
    assert int(total) == R * 6
    got = sorted(int(vals[r, i]) for r in range(R) for i in range(counts[r]))
    assert got == sorted(s * 100 + k for s in range(R) for k in range(6))


# ---------------------------------------------------------------- rebalance
def test_rebalance_scatter_matches_sort(mesh_pods222):
    """Topology-aware rebalance (global + intra scope) under the scatter
    marshal — including the intra path's derived fast-axis sub-config."""
    from repro.core import rebalance
    from repro.core import types as T  # noqa: F401

    def run(marshal, scope):
        cfg = ForwardConfig(
            AXES3, R, CAP, exchange="hierarchical", level_sizes=(2, 2, 2),
            marshal=marshal,
        )

        def bal(_x):
            me = jax.lax.axis_index(AXES3)
            n = jnp.where(me % 2 == 0, 40, 2)
            proto_val = (jnp.arange(CAP) + me * 1000).astype(jnp.float32)
            q = WorkQueue(
                items=Item(val=proto_val, src=me * jnp.ones(CAP, jnp.int32)),
                dest=jnp.full((CAP,), DISCARD, jnp.int32),
                count=n.astype(jnp.int32),
                drops=jnp.zeros((), jnp.int32),
            )
            nq, total = rebalance(q, cfg, scope=scope)
            return nq.items.val, nq.count[None], total

        f = jax.jit(
            compat.shard_map(
                bal, mesh=mesh_pods222, in_specs=P(AXES3),
                out_specs=(P(AXES3), P(AXES3), P()),
            )
        )
        return [np.asarray(x) for x in f(jnp.arange(8.0))]

    for scope in ("global", "intra"):
        a = run("scatter", scope)
        b = run("sort", scope)
        np.testing.assert_array_equal(a[1], b[1], err_msg=scope)
        av, bv = a[0].reshape(R, CAP), b[0].reshape(R, CAP)
        for r in range(R):
            n = int(a[1].reshape(-1)[r])
            np.testing.assert_array_equal(av[r][:n], bv[r][:n], err_msg=scope)
        assert int(a[2]) == int(b[2])


# ------------------------------------------- drop accounting (exactly once)
@pytest.mark.parametrize("marshal", ["sort", "scatter"])
def test_multi_tier_clamps_count_each_drop_exactly_once(mesh_pods222, marshal):
    """One hot segment (everyone → rank 0) overflows EVERY tier of a
    (2, 2, 2) route with level_capacities=(4, 4, 4).  Exact accounting:

      stage device: each of 8 ranks clamps its 10-row dest-0 sub-segment to 4
                    → 6·8 = 48 drops;
      stage node:   ranks with device digit 0 hold [4, 4] rows for dest 0,
                    clamp the 8-row concatenation to 4 → 4·4 = 16 drops;
      stage pod:    ranks 0 and 4 hold [4, 4], clamp to 4 → 4·2 = 8 drops;
      receiver:     rank 0 gets 4 + 4 = 8 ≤ capacity → 0 drops.

    An item clamped at one tier must never re-enter a later tier's (or the
    receiver's) count: globally received + dropped == emitted with these
    EXACT stage numbers — a double count would inflate drops past 72."""
    cfg = ForwardConfig(
        AXES3, R, CAP, exchange="hierarchical", level_sizes=(2, 2, 2),
        level_capacities=(4, 4, 4), marshal=marshal,
    )
    fn = _make_fn(mesh_pods222, cfg, AXES3)
    counts = np.full(R, 10, np.int32)
    dest = np.zeros((R, CAP), np.int32)
    val = np.random.default_rng(4).normal(size=(R, CAP)).astype(np.float32)
    _v, _s, out_counts, out_drops, total = fn(
        jnp.asarray(val).reshape(-1),
        jnp.asarray(dest).reshape(-1),
        jnp.asarray(counts),
    )
    out_counts = np.asarray(out_counts).reshape(-1)
    assert out_counts[0] == 8 and out_counts[1:].sum() == 0, out_counts
    assert int(np.asarray(out_drops).sum()) == 48 + 16 + 8, np.asarray(out_drops)
    assert int(total) + int(np.asarray(out_drops).sum()) == 8 * 10
    assert int(total) == 8


@pytest.mark.parametrize("marshal", ["sort", "scatter"])
def test_flat_sender_and_receiver_clamps_count_once(mesh8, marshal):
    """Flat analogue: sender slot clamp (10 → 4 per source) and receiver
    capacity clamp (32 → CAP would not fire at 64, so emit 10 → recv 8·10=80
    > 64) must sum, never overlap, in the drop counter."""
    cfg = ForwardConfig(
        "data", R, CAP, exchange="padded", peer_capacity=10, marshal=marshal
    )
    fn = _make_fn(mesh8, cfg)
    counts = np.full(R, 10, np.int32)
    dest = np.zeros((R, CAP), np.int32)  # everyone → rank 0
    val = np.random.default_rng(6).normal(size=(R, CAP)).astype(np.float32)
    _v, _s, out_counts, out_drops, total = fn(
        jnp.asarray(val).reshape(-1),
        jnp.asarray(dest).reshape(-1),
        jnp.asarray(counts),
    )
    out_counts = np.asarray(out_counts).reshape(-1)
    # no sender clamp (10 ≤ 10); receiver: 80 arrive, 64 fit, 16 dropped
    assert out_counts[0] == CAP, out_counts
    assert int(np.asarray(out_drops).sum()) == 8 * 10 - CAP
    assert int(total) == CAP

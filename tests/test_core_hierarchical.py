"""Hierarchical two-stage exchange (ISSUE 2): parity, accounting, config.

The `hierarchical` backend must be *observationally identical* to the flat
backends — same counts, same drops, bit-exact placement — because global
ranks are node-major and both stages preserve (source rank, lane) order.  The
oracle is ``exchange_onehot`` (a deliberately different code path).  With
ample stage capacities the ONLY drops either backend takes are
receiver-capacity clamps, so parity holds even for the all-items-to-one-rank
hot spot; with the default (tight) stage capacities the conservation law
``received + dropped == emitted`` still holds globally.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis — deterministic stub
    from _hypothesis_stub import given, settings, st
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import DISCARD, ForwardConfig, WorkQueue, forward_work, work_item

R, CAP = 8, 64
AXES = ("node", "device")


@work_item
@dataclasses.dataclass
class Item:
    val: jax.Array
    src: jax.Array


def _make_fn(mesh, cfg, axes=AXES):
    def fwd(items_val, dest, counts):
        me = jax.lax.axis_index(axes)
        q = WorkQueue(
            items=Item(val=items_val, src=me * jnp.ones(CAP, jnp.int32)),
            dest=dest,
            count=counts[0],
            drops=jnp.zeros((), jnp.int32),
        )
        nq, total = forward_work(q, cfg)
        return nq.items.val, nq.items.src, nq.count[None], nq.drops[None], total

    return jax.jit(
        compat.shard_map(
            fwd, mesh=mesh,
            in_specs=(P(axes), P(axes), P(axes)),
            out_specs=(P(axes), P(axes), P(axes), P(axes), P()),
        )
    )


def _ample(fast_size, **kw):
    """Stage capacities so large no stage-A/B clamp can ever fire: the only
    remaining drop site is the receiver capacity — same as the oracle's."""
    return ForwardConfig(
        AXES, R, CAP, exchange="hierarchical", fast_size=fast_size,
        peer_capacity=CAP, node_capacity=fast_size * CAP, **kw,
    )


def _run_pair(hier_fn, onehot_fn, counts, dest, val):
    args = (
        jnp.asarray(val).reshape(-1),
        jnp.asarray(dest).reshape(-1),
        jnp.asarray(counts),
    )
    h = [np.asarray(x) for x in hier_fn(*args)]
    o = [np.asarray(x) for x in onehot_fn(*args)]
    np.testing.assert_array_equal(h[2], o[2], err_msg="per-rank receive counts")
    hv, hs = h[0].reshape(R, CAP), h[1].reshape(R, CAP)
    ov, os_ = o[0].reshape(R, CAP), o[1].reshape(R, CAP)
    for r in range(R):  # valid prefixes bit-exact; tails are garbage
        n = int(h[2].reshape(-1)[r])
        np.testing.assert_array_equal(hv[r][:n], ov[r][:n])
        np.testing.assert_array_equal(hs[r][:n], os_[r][:n])
    assert int(h[3].sum()) == int(o[3].sum()), "global drops"
    assert int(h[4]) == int(o[4]), "termination total"
    lane = np.arange(CAP)[None, :]
    emitted = int(((lane < counts[:, None]) & (dest >= 0) & (dest < R)).sum())
    assert int(h[2].sum()) + int(h[3].sum()) == emitted, "conservation"


@pytest.fixture(scope="module")
def fns24(mesh_nodes24):
    return (
        _make_fn(mesh_nodes24, _ample(4)),
        _make_fn(mesh_nodes24, ForwardConfig(AXES, R, CAP, exchange="onehot")),
    )


@pytest.fixture(scope="module")
def fns42(mesh_nodes42):
    return (
        _make_fn(mesh_nodes42, _ample(2)),
        _make_fn(mesh_nodes42, ForwardConfig(AXES, R, CAP, exchange="onehot")),
    )


@given(data=st.data())
@settings(max_examples=15, deadline=None)
def test_matches_onehot_bitwise_2x4(fns24, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(-1, R, (R, CAP)).astype(np.int32)  # incl. DISCARD lanes
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _run_pair(*fns24, counts, dest, val)


@given(data=st.data())
@settings(max_examples=10, deadline=None)
def test_matches_onehot_bitwise_4x2(fns42, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(0, R, (R, CAP)).astype(np.int32)
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _run_pair(*fns42, counts, dest, val)


def test_hotspot_all_to_one_rank_matches_onehot(fns24):
    """Everyone floods rank 0 at full queue: R·CAP items into one CAP-row
    queue.  Receiver clamp is the only drop site for both backends, so
    placement, counts, and drops must match exactly."""
    counts = np.full(R, CAP, np.int32)
    dest = np.zeros((R, CAP), np.int32)
    val = np.random.default_rng(1).normal(size=(R, CAP)).astype(np.float32)
    _run_pair(*fns24, counts, dest, val)


def test_discard_only_is_a_noop(fns24):
    counts = np.full(R, CAP, np.int32)
    dest = np.full((R, CAP), DISCARD, np.int32)
    val = np.zeros((R, CAP), np.float32)
    _run_pair(*fns24, counts, dest, val)


@given(data=st.data())
@settings(max_examples=10, deadline=None)
def test_tight_slots_conserve_items_plus_drops(mesh_nodes24, data):
    """With the DEFAULT (tight) stage capacities, stage-A and stage-B clamps
    fire under skew; every clamped item must land in `drops` — globally,
    received + dropped == emitted."""
    fn = _make_fn(
        mesh_nodes24,
        ForwardConfig(AXES, R, CAP, exchange="hierarchical", fast_size=4),
    )
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    # heavy skew: half the ranks route everything to rank 0
    dest = rng.integers(0, R, (R, CAP)).astype(np.int32)
    dest[::2] = 0
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _v, _s, out_counts, out_drops, total = fn(
        jnp.asarray(val).reshape(-1),
        jnp.asarray(dest).reshape(-1),
        jnp.asarray(counts),
    )
    received = int(np.asarray(out_counts).sum())
    dropped = int(np.asarray(out_drops).sum())
    assert received + dropped == int(counts.sum())
    assert int(total) == received


def test_pallas_path_matches_xla_path(mesh_nodes24):
    fn_p = _make_fn(mesh_nodes24, _ample(4, use_pallas=True))
    fn_x = _make_fn(mesh_nodes24, _ample(4))
    rng = np.random.default_rng(7)
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(0, R, (R, CAP)).astype(np.int32)
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    args = (
        jnp.asarray(val).reshape(-1),
        jnp.asarray(dest).reshape(-1),
        jnp.asarray(counts),
    )
    p = [np.asarray(x) for x in fn_p(*args)]
    x = [np.asarray(x) for x in fn_x(*args)]
    np.testing.assert_array_equal(p[2], x[2])
    for r in range(R):
        n = int(p[2].reshape(-1)[r])
        np.testing.assert_array_equal(
            p[0].reshape(R, CAP)[r][:n], x[0].reshape(R, CAP)[r][:n]
        )
    assert int(p[3].sum()) == int(x[3].sum())


def test_cycling_on_node_mesh_delivers_everything(mesh_nodes42):
    """§6.3 cycling with hierarchical hops: R node-major ring hops (fast-axis
    ppermute + a slow-axis hop at each node boundary) deliver every item."""
    from repro.core import enqueue, make_queue
    from repro.core.cycling import deliver_by_cycling

    cfg = ForwardConfig(AXES, R, CAP, exchange="hierarchical", fast_size=2)

    def kernel(_x):
        proto = Item(val=jnp.zeros(()), src=jnp.zeros((), jnp.int32))
        q = make_queue(proto, CAP)
        me = jax.lax.axis_index(AXES)
        n = 6
        k = jnp.arange(n)
        items = Item(
            val=(k + me * 100).astype(jnp.float32),
            src=me * jnp.ones(n, jnp.int32),
        )
        q = enqueue(q, items, ((me * 3 + k) % R).astype(jnp.int32), jnp.ones(n, bool))
        absorbed, total = deliver_by_cycling(q, cfg)
        return absorbed.count[None], total, absorbed.items.val

    f = jax.jit(
        compat.shard_map(
            kernel, mesh=mesh_nodes42, in_specs=P(AXES),
            out_specs=(P(AXES), P(), P(AXES)),
        )
    )
    counts, total, vals = f(jnp.arange(8.0))
    counts = np.asarray(counts)
    vals = np.asarray(vals).reshape(R, CAP)
    assert int(total) == R * 6
    got = sorted(int(vals[r, i]) for r in range(R) for i in range(counts[r]))
    assert got == sorted(s * 100 + k for s in range(R) for k in range(6))


@pytest.mark.parametrize(
    "nodes,devs",
    [(1, 8), (8, 1)],
    ids=["single-node", "single-lane"],
)
def test_degenerate_axes_match_onehot(nodes, devs):
    """Extent-1 axes take dedicated identity paths (no stage-B collective on
    a single node; sort composed into stage B on a single lane) — both must
    stay bit-exact with the oracle, hot-spot included."""
    from repro.launch.mesh import make_node_mesh

    mesh = make_node_mesh(nodes, devs)
    hier = _make_fn(
        mesh,
        ForwardConfig(
            AXES, R, CAP, exchange="hierarchical", fast_size=devs,
            peer_capacity=CAP, node_capacity=devs * CAP,
        ),
    )
    onehot = _make_fn(mesh, ForwardConfig(AXES, R, CAP, exchange="onehot"))
    rng = np.random.default_rng(nodes * 10 + devs)
    for hotspot in (False, True):
        counts = (
            np.full(R, CAP, np.int32)
            if hotspot
            else rng.integers(0, CAP + 1, R).astype(np.int32)
        )
        dest = (
            np.zeros((R, CAP), np.int32)
            if hotspot
            else rng.integers(0, R, (R, CAP)).astype(np.int32)
        )
        val = rng.normal(size=(R, CAP)).astype(np.float32)
        _run_pair(hier, onehot, counts, dest, val)


# ------------------------------------------------------ 3-level (pod, node, device)
AXES3 = ("pod", "node", "device")


def _ample3(level_sizes, **kw):
    """Per-tier stage capacities so large no stage clamp can ever fire (stage
    l's buffer holds at most CAP · prod(faster sizes) rows): the only
    remaining drop site is the receiver capacity — same as the oracle's."""
    caps, mult = [], 1
    for a in reversed(level_sizes):
        caps.append(CAP * mult)
        mult *= a
    return ForwardConfig(
        AXES3, R, CAP, exchange="hierarchical", level_sizes=level_sizes,
        level_capacities=tuple(reversed(caps)), **kw,
    )


@pytest.fixture(scope="module")
def fns222(mesh_pods222):
    return (
        _make_fn(mesh_pods222, _ample3((2, 2, 2)), AXES3),
        _make_fn(
            mesh_pods222, ForwardConfig(AXES3, R, CAP, exchange="onehot"), AXES3
        ),
    )


@given(data=st.data())
@settings(max_examples=15, deadline=None)
def test_3level_matches_onehot_bitwise(fns222, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(-1, R, (R, CAP)).astype(np.int32)  # incl. DISCARD lanes
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _run_pair(*fns222, counts, dest, val)


def test_3level_hotspot_matches_onehot(fns222):
    """Everyone floods rank 0 at full queue across all three tiers."""
    counts = np.full(R, CAP, np.int32)
    dest = np.zeros((R, CAP), np.int32)
    val = np.random.default_rng(3).normal(size=(R, CAP)).astype(np.float32)
    _run_pair(*fns222, counts, dest, val)


@given(data=st.data())
@settings(max_examples=8, deadline=None)
def test_3level_tight_slots_conserve_items_plus_drops(mesh_pods222, data):
    """Default (tight, load-proportional) per-tier capacities under skew:
    every stage clamp must land in `drops` — received + dropped == emitted."""
    fn = _make_fn(
        mesh_pods222,
        ForwardConfig(
            AXES3, R, CAP, exchange="hierarchical", level_sizes=(2, 2, 2)
        ),
        AXES3,
    )
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(0, R, (R, CAP)).astype(np.int32)
    dest[::2] = 0  # heavy skew across pods and nodes
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _v, _s, out_counts, out_drops, total = fn(
        jnp.asarray(val).reshape(-1),
        jnp.asarray(dest).reshape(-1),
        jnp.asarray(counts),
    )
    received = int(np.asarray(out_counts).sum())
    dropped = int(np.asarray(out_drops).sum())
    assert received + dropped == int(counts.sum())
    assert int(total) == received


@pytest.mark.parametrize(
    "shape",
    [(1, 2, 4), (2, 1, 4), (2, 4, 1), (1, 1, 8), (8, 1, 1), (1, 8, 1)],
    ids=lambda s: "x".join(map(str, s)),
)
def test_3level_degenerate_axes_match_onehot(shape):
    """Extent-1 tiers anywhere in the hierarchy skip their stage — the route
    must stay bit-exact with the oracle, hot-spot included."""
    from repro.launch.mesh import make_pod_mesh

    mesh = make_pod_mesh(*shape)
    hier = _make_fn(mesh, _ample3(shape), AXES3)
    onehot = _make_fn(
        mesh, ForwardConfig(AXES3, R, CAP, exchange="onehot"), AXES3
    )
    rng = np.random.default_rng(sum(shape))
    for hotspot in (False, True):
        counts = (
            np.full(R, CAP, np.int32)
            if hotspot
            else rng.integers(0, CAP + 1, R).astype(np.int32)
        )
        dest = (
            np.zeros((R, CAP), np.int32)
            if hotspot
            else rng.integers(0, R, (R, CAP)).astype(np.int32)
        )
        val = rng.normal(size=(R, CAP)).astype(np.float32)
        _run_pair(hier, onehot, counts, dest, val)


def test_3level_pallas_path_matches_xla_path(mesh_pods222):
    fn_p = _make_fn(mesh_pods222, _ample3((2, 2, 2), use_pallas=True), AXES3)
    fn_x = _make_fn(mesh_pods222, _ample3((2, 2, 2)), AXES3)
    rng = np.random.default_rng(11)
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(0, R, (R, CAP)).astype(np.int32)
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    args = (
        jnp.asarray(val).reshape(-1),
        jnp.asarray(dest).reshape(-1),
        jnp.asarray(counts),
    )
    p = [np.asarray(x) for x in fn_p(*args)]
    x = [np.asarray(x) for x in fn_x(*args)]
    np.testing.assert_array_equal(p[2], x[2])
    for r in range(R):
        n = int(p[2].reshape(-1)[r])
        np.testing.assert_array_equal(
            p[0].reshape(R, CAP)[r][:n], x[0].reshape(R, CAP)[r][:n]
        )
    assert int(p[3].sum()) == int(x[3].sum())


def test_joint_tier_axes_match_onehot(mesh_pods222):
    """A tier may group several mesh axes into one joint fabric: the 2-level
    route over ((pod, node), device) must equal the oracle on the same mesh."""
    hier = _make_fn(
        mesh_pods222,
        ForwardConfig(
            (("pod", "node"), "device"), R, CAP, exchange="hierarchical",
            level_sizes=(4, 2), level_capacities=(2 * CAP, CAP),
        ),
        AXES3,
    )
    onehot = _make_fn(
        mesh_pods222, ForwardConfig(AXES3, R, CAP, exchange="onehot"), AXES3
    )
    rng = np.random.default_rng(17)
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(0, R, (R, CAP)).astype(np.int32)
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _run_pair(hier, onehot, counts, dest, val)


def test_joint_tier_rafi_context_forwards(mesh_pods222):
    """RafiContext must accept a joint-tier axis_name end to end: the
    PartitionSpec side flattens the nesting while the config keeps the tier
    structure (regression: P((('pod','node'),'device')) is not a legal spec)."""
    from repro.core import RafiContext, enqueue

    proto = Item(val=jnp.zeros(()), src=jnp.zeros((), jnp.int32))
    ctx = RafiContext(
        mesh_pods222, proto, axis_name=(("pod", "node"), "device"),
        capacity=CAP, exchange="hierarchical",
    )
    assert ctx.cfg.level_sizes == (4, 2)

    def fill(_x):
        from repro.core.context import _stack_queue

        me = jax.lax.axis_index(("pod", "node", "device"))
        lq = ctx.local_queue()
        lq = enqueue(
            lq,
            Item(val=jnp.arange(4.0) + me * 10, src=me * jnp.ones(4, jnp.int32)),
            ((me + jnp.arange(4)) % R).astype(jnp.int32),
            jnp.ones(4, bool),
        )
        return _stack_queue(lq)

    from jax.sharding import PartitionSpec as PS

    q = ctx.shard(
        fill, in_specs=PS(("pod", "node", "device")), out_specs=ctx.queue_specs()
    )(jnp.arange(8.0))
    nq, total = ctx.forward_rays()(q)
    assert int(total) == R * 4
    assert np.asarray(nq.count).sum() == R * 4


def test_joint_tier_cycling_delivers_everything(mesh_pods222):
    """deliver_by_cycling must flatten joint-tier axis names for its
    ppermute/psum (regression: nested tuples are not bindable axis names)."""
    from repro.core import enqueue, make_queue
    from repro.core.cycling import deliver_by_cycling

    axes = ("pod", "node", "device")
    cfg = ForwardConfig(
        (("pod", "node"), "device"), R, CAP, exchange="hierarchical",
        level_sizes=(4, 2),
    )

    def kernel(_x):
        proto = Item(val=jnp.zeros(()), src=jnp.zeros((), jnp.int32))
        q = make_queue(proto, CAP)
        me = jax.lax.axis_index(axes)
        n = 5
        k = jnp.arange(n)
        items = Item(
            val=(k + me * 100).astype(jnp.float32),
            src=me * jnp.ones(n, jnp.int32),
        )
        q = enqueue(q, items, ((me * 3 + k) % R).astype(jnp.int32), jnp.ones(n, bool))
        absorbed, total = deliver_by_cycling(q, cfg)
        return absorbed.count[None], total, absorbed.items.val

    f = jax.jit(
        compat.shard_map(
            kernel, mesh=mesh_pods222, in_specs=P(axes),
            out_specs=(P(axes), P(), P(axes)),
        )
    )
    counts, total, vals = f(jnp.arange(8.0))
    counts = np.asarray(counts)
    vals = np.asarray(vals).reshape(R, CAP)
    assert int(total) == R * 5
    got = sorted(int(vals[r, i]) for r in range(R) for i in range(counts[r]))
    assert got == sorted(s * 100 + k for s in range(R) for k in range(5))


# ------------------------------------------------- ForwardConfig validation


def test_config_rejects_flat_axis():
    with pytest.raises(ValueError, match="slowest"):
        ForwardConfig("data", R, CAP, exchange="hierarchical", fast_size=4)


def test_config_rejects_missing_fast_size():
    with pytest.raises(ValueError, match="fast_size"):
        ForwardConfig(AXES, R, CAP, exchange="hierarchical")


def test_config_rejects_non_dividing_fast_size():
    with pytest.raises(ValueError, match="divide"):
        ForwardConfig(AXES, R, CAP, exchange="hierarchical", fast_size=3)


def test_config_three_axes_need_level_sizes():
    """N>2 tiers cannot be derived from the 2-level fast_size alias alone."""
    with pytest.raises(ValueError, match="level_sizes"):
        ForwardConfig(AXES3, R, CAP, exchange="hierarchical", fast_size=4)
    cfg = ForwardConfig(
        AXES3, R, CAP, exchange="hierarchical", level_sizes=(2, 2, 2)
    )
    assert cfg.level_sizes == (2, 2, 2)
    assert len(cfg.level_capacities) == 3
    # legacy aliases mirror the fastest / slowest tiers
    assert cfg.fast_size == 2
    assert cfg.peer_capacity == cfg.level_capacities[-1]
    assert cfg.node_capacity == cfg.level_capacities[0]


def test_config_rejects_bad_level_sizes():
    with pytest.raises(ValueError, match="multiply"):
        ForwardConfig(
            AXES3, R, CAP, exchange="hierarchical", level_sizes=(2, 2, 4)
        )
    with pytest.raises(ValueError, match="one rank count per"):
        ForwardConfig(
            AXES3, R, CAP, exchange="hierarchical", level_sizes=(2, 4)
        )
    with pytest.raises(ValueError, match="contradicts"):
        ForwardConfig(
            AXES, R, CAP, exchange="hierarchical", level_sizes=(2, 4), fast_size=2
        )
    with pytest.raises(ValueError, match="one segment size per"):
        ForwardConfig(
            AXES3, R, CAP, exchange="hierarchical", level_sizes=(2, 2, 2),
            level_capacities=(8, 8),
        )
    with pytest.raises(ValueError, match="contradicts"):
        ForwardConfig(
            AXES, R, CAP, exchange="hierarchical", level_sizes=(2, 4),
            level_capacities=(8, 8), peer_capacity=16,
        )


def test_config_rejects_hierarchical_fields_on_flat_backends():
    """Flat backends would silently ignore topology fields — reject them."""
    for exchange in ("padded", "ragged", "onehot"):
        with pytest.raises(ValueError, match="hierarchical"):
            ForwardConfig("data", R, CAP, exchange=exchange, fast_size=4)
        with pytest.raises(ValueError, match="hierarchical"):
            ForwardConfig("data", R, CAP, exchange=exchange, node_capacity=8)
        with pytest.raises(ValueError, match="hierarchical"):
            ForwardConfig("data", R, CAP, exchange=exchange, level_sizes=(2, 4))
        with pytest.raises(ValueError, match="hierarchical"):
            ForwardConfig(
                "data", R, CAP, exchange=exchange, level_capacities=(8, 8)
            )


def test_config_rejects_peer_capacity_on_slotless_backends():
    """ragged segments are contiguous and onehot gathers everything — a
    peer_capacity there is a config bug, not a tuning knob."""
    for exchange in ("ragged", "onehot"):
        with pytest.raises(ValueError, match="peer_capacity"):
            ForwardConfig("data", R, CAP, exchange=exchange, peer_capacity=8)


def test_config_rejects_nonpositive_shapes():
    with pytest.raises(ValueError, match="positive"):
        ForwardConfig("data", 0, CAP, exchange="padded")
    with pytest.raises(ValueError, match="positive"):
        ForwardConfig("data", R, 0, exchange="padded")
    with pytest.raises(ValueError, match="sort_method"):
        ForwardConfig("data", R, CAP, exchange="padded", sort_method="bogus")


def test_default_capacities_match_backend_fanout():
    """The peer_capacity default must track the backend's true fan-out:
    R per-rank slots for flat padded, fast_size per-lane slots (stage A) and
    R/fast_size per-node segments (stage B) for hierarchical."""
    flat = ForwardConfig("data", R, CAP, exchange="padded")
    assert flat.peer_capacity == 2 * -(-CAP // R)
    hier = ForwardConfig(AXES, R, CAP, exchange="hierarchical", fast_size=4)
    assert hier.peer_capacity == 2 * -(-CAP // 4)  # stage A: F peers
    assert hier.node_capacity == 2 * -(-CAP // 2)  # stage B: N=2 nodes
    hier42 = ForwardConfig(AXES, R, CAP, exchange="hierarchical", fast_size=2)
    assert hier42.peer_capacity == 2 * -(-CAP // 2)
    assert hier42.node_capacity == 2 * -(-CAP // 4)
    # explicit values always win
    explicit = ForwardConfig(
        AXES, R, CAP, exchange="hierarchical", fast_size=4,
        peer_capacity=7, node_capacity=11,
    )
    assert explicit.peer_capacity == 7 and explicit.node_capacity == 11

"""Hierarchical two-stage exchange (ISSUE 2): parity, accounting, config.

The `hierarchical` backend must be *observationally identical* to the flat
backends — same counts, same drops, bit-exact placement — because global
ranks are node-major and both stages preserve (source rank, lane) order.  The
oracle is ``exchange_onehot`` (a deliberately different code path).  With
ample stage capacities the ONLY drops either backend takes are
receiver-capacity clamps, so parity holds even for the all-items-to-one-rank
hot spot; with the default (tight) stage capacities the conservation law
``received + dropped == emitted`` still holds globally.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis — deterministic stub
    from _hypothesis_stub import given, settings, st
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import DISCARD, ForwardConfig, WorkQueue, forward_work, work_item

R, CAP = 8, 64
AXES = ("node", "device")


@work_item
@dataclasses.dataclass
class Item:
    val: jax.Array
    src: jax.Array


def _make_fn(mesh, cfg):
    def fwd(items_val, dest, counts):
        me = jax.lax.axis_index(AXES)
        q = WorkQueue(
            items=Item(val=items_val, src=me * jnp.ones(CAP, jnp.int32)),
            dest=dest,
            count=counts[0],
            drops=jnp.zeros((), jnp.int32),
        )
        nq, total = forward_work(q, cfg)
        return nq.items.val, nq.items.src, nq.count[None], nq.drops[None], total

    return jax.jit(
        compat.shard_map(
            fwd, mesh=mesh,
            in_specs=(P(AXES), P(AXES), P(AXES)),
            out_specs=(P(AXES), P(AXES), P(AXES), P(AXES), P()),
        )
    )


def _ample(fast_size, **kw):
    """Stage capacities so large no stage-A/B clamp can ever fire: the only
    remaining drop site is the receiver capacity — same as the oracle's."""
    return ForwardConfig(
        AXES, R, CAP, exchange="hierarchical", fast_size=fast_size,
        peer_capacity=CAP, node_capacity=fast_size * CAP, **kw,
    )


def _run_pair(hier_fn, onehot_fn, counts, dest, val):
    args = (
        jnp.asarray(val).reshape(-1),
        jnp.asarray(dest).reshape(-1),
        jnp.asarray(counts),
    )
    h = [np.asarray(x) for x in hier_fn(*args)]
    o = [np.asarray(x) for x in onehot_fn(*args)]
    np.testing.assert_array_equal(h[2], o[2], err_msg="per-rank receive counts")
    hv, hs = h[0].reshape(R, CAP), h[1].reshape(R, CAP)
    ov, os_ = o[0].reshape(R, CAP), o[1].reshape(R, CAP)
    for r in range(R):  # valid prefixes bit-exact; tails are garbage
        n = int(h[2].reshape(-1)[r])
        np.testing.assert_array_equal(hv[r][:n], ov[r][:n])
        np.testing.assert_array_equal(hs[r][:n], os_[r][:n])
    assert int(h[3].sum()) == int(o[3].sum()), "global drops"
    assert int(h[4]) == int(o[4]), "termination total"
    lane = np.arange(CAP)[None, :]
    emitted = int(((lane < counts[:, None]) & (dest >= 0) & (dest < R)).sum())
    assert int(h[2].sum()) + int(h[3].sum()) == emitted, "conservation"


@pytest.fixture(scope="module")
def fns24(mesh_nodes24):
    return (
        _make_fn(mesh_nodes24, _ample(4)),
        _make_fn(mesh_nodes24, ForwardConfig(AXES, R, CAP, exchange="onehot")),
    )


@pytest.fixture(scope="module")
def fns42(mesh_nodes42):
    return (
        _make_fn(mesh_nodes42, _ample(2)),
        _make_fn(mesh_nodes42, ForwardConfig(AXES, R, CAP, exchange="onehot")),
    )


@given(data=st.data())
@settings(max_examples=15, deadline=None)
def test_matches_onehot_bitwise_2x4(fns24, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(-1, R, (R, CAP)).astype(np.int32)  # incl. DISCARD lanes
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _run_pair(*fns24, counts, dest, val)


@given(data=st.data())
@settings(max_examples=10, deadline=None)
def test_matches_onehot_bitwise_4x2(fns42, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(0, R, (R, CAP)).astype(np.int32)
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _run_pair(*fns42, counts, dest, val)


def test_hotspot_all_to_one_rank_matches_onehot(fns24):
    """Everyone floods rank 0 at full queue: R·CAP items into one CAP-row
    queue.  Receiver clamp is the only drop site for both backends, so
    placement, counts, and drops must match exactly."""
    counts = np.full(R, CAP, np.int32)
    dest = np.zeros((R, CAP), np.int32)
    val = np.random.default_rng(1).normal(size=(R, CAP)).astype(np.float32)
    _run_pair(*fns24, counts, dest, val)


def test_discard_only_is_a_noop(fns24):
    counts = np.full(R, CAP, np.int32)
    dest = np.full((R, CAP), DISCARD, np.int32)
    val = np.zeros((R, CAP), np.float32)
    _run_pair(*fns24, counts, dest, val)


@given(data=st.data())
@settings(max_examples=10, deadline=None)
def test_tight_slots_conserve_items_plus_drops(mesh_nodes24, data):
    """With the DEFAULT (tight) stage capacities, stage-A and stage-B clamps
    fire under skew; every clamped item must land in `drops` — globally,
    received + dropped == emitted."""
    fn = _make_fn(
        mesh_nodes24,
        ForwardConfig(AXES, R, CAP, exchange="hierarchical", fast_size=4),
    )
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    # heavy skew: half the ranks route everything to rank 0
    dest = rng.integers(0, R, (R, CAP)).astype(np.int32)
    dest[::2] = 0
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    _v, _s, out_counts, out_drops, total = fn(
        jnp.asarray(val).reshape(-1),
        jnp.asarray(dest).reshape(-1),
        jnp.asarray(counts),
    )
    received = int(np.asarray(out_counts).sum())
    dropped = int(np.asarray(out_drops).sum())
    assert received + dropped == int(counts.sum())
    assert int(total) == received


def test_pallas_path_matches_xla_path(mesh_nodes24):
    fn_p = _make_fn(mesh_nodes24, _ample(4, use_pallas=True))
    fn_x = _make_fn(mesh_nodes24, _ample(4))
    rng = np.random.default_rng(7)
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = rng.integers(0, R, (R, CAP)).astype(np.int32)
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    args = (
        jnp.asarray(val).reshape(-1),
        jnp.asarray(dest).reshape(-1),
        jnp.asarray(counts),
    )
    p = [np.asarray(x) for x in fn_p(*args)]
    x = [np.asarray(x) for x in fn_x(*args)]
    np.testing.assert_array_equal(p[2], x[2])
    for r in range(R):
        n = int(p[2].reshape(-1)[r])
        np.testing.assert_array_equal(
            p[0].reshape(R, CAP)[r][:n], x[0].reshape(R, CAP)[r][:n]
        )
    assert int(p[3].sum()) == int(x[3].sum())


def test_cycling_on_node_mesh_delivers_everything(mesh_nodes42):
    """§6.3 cycling with hierarchical hops: R node-major ring hops (fast-axis
    ppermute + a slow-axis hop at each node boundary) deliver every item."""
    from repro.core import enqueue, make_queue
    from repro.core.cycling import deliver_by_cycling

    cfg = ForwardConfig(AXES, R, CAP, exchange="hierarchical", fast_size=2)

    def kernel(_x):
        proto = Item(val=jnp.zeros(()), src=jnp.zeros((), jnp.int32))
        q = make_queue(proto, CAP)
        me = jax.lax.axis_index(AXES)
        n = 6
        k = jnp.arange(n)
        items = Item(
            val=(k + me * 100).astype(jnp.float32),
            src=me * jnp.ones(n, jnp.int32),
        )
        q = enqueue(q, items, ((me * 3 + k) % R).astype(jnp.int32), jnp.ones(n, bool))
        absorbed, total = deliver_by_cycling(q, cfg)
        return absorbed.count[None], total, absorbed.items.val

    f = jax.jit(
        compat.shard_map(
            kernel, mesh=mesh_nodes42, in_specs=P(AXES),
            out_specs=(P(AXES), P(), P(AXES)),
        )
    )
    counts, total, vals = f(jnp.arange(8.0))
    counts = np.asarray(counts)
    vals = np.asarray(vals).reshape(R, CAP)
    assert int(total) == R * 6
    got = sorted(int(vals[r, i]) for r in range(R) for i in range(counts[r]))
    assert got == sorted(s * 100 + k for s in range(R) for k in range(6))


@pytest.mark.parametrize(
    "nodes,devs",
    [(1, 8), (8, 1)],
    ids=["single-node", "single-lane"],
)
def test_degenerate_axes_match_onehot(nodes, devs):
    """Extent-1 axes take dedicated identity paths (no stage-B collective on
    a single node; sort composed into stage B on a single lane) — both must
    stay bit-exact with the oracle, hot-spot included."""
    from repro.launch.mesh import make_node_mesh

    mesh = make_node_mesh(nodes, devs)
    hier = _make_fn(
        mesh,
        ForwardConfig(
            AXES, R, CAP, exchange="hierarchical", fast_size=devs,
            peer_capacity=CAP, node_capacity=devs * CAP,
        ),
    )
    onehot = _make_fn(mesh, ForwardConfig(AXES, R, CAP, exchange="onehot"))
    rng = np.random.default_rng(nodes * 10 + devs)
    for hotspot in (False, True):
        counts = (
            np.full(R, CAP, np.int32)
            if hotspot
            else rng.integers(0, CAP + 1, R).astype(np.int32)
        )
        dest = (
            np.zeros((R, CAP), np.int32)
            if hotspot
            else rng.integers(0, R, (R, CAP)).astype(np.int32)
        )
        val = rng.normal(size=(R, CAP)).astype(np.float32)
        _run_pair(hier, onehot, counts, dest, val)


# ------------------------------------------------- ForwardConfig validation
def test_config_rejects_flat_axis():
    with pytest.raises(ValueError, match=r"\(slow, fast\)"):
        ForwardConfig("data", R, CAP, exchange="hierarchical", fast_size=4)


def test_config_rejects_missing_fast_size():
    with pytest.raises(ValueError, match="fast_size"):
        ForwardConfig(AXES, R, CAP, exchange="hierarchical")


def test_config_rejects_non_dividing_fast_size():
    with pytest.raises(ValueError, match="divide"):
        ForwardConfig(AXES, R, CAP, exchange="hierarchical", fast_size=3)


def test_config_rejects_three_axes():
    with pytest.raises(ValueError, match=r"\(slow, fast\)"):
        ForwardConfig(
            ("pod", "node", "device"), R, CAP, exchange="hierarchical", fast_size=4
        )


def test_default_capacities_match_backend_fanout():
    """The peer_capacity default must track the backend's true fan-out:
    R per-rank slots for flat padded, fast_size per-lane slots (stage A) and
    R/fast_size per-node segments (stage B) for hierarchical."""
    flat = ForwardConfig("data", R, CAP, exchange="padded")
    assert flat.peer_capacity == 2 * -(-CAP // R)
    hier = ForwardConfig(AXES, R, CAP, exchange="hierarchical", fast_size=4)
    assert hier.peer_capacity == 2 * -(-CAP // 4)  # stage A: F peers
    assert hier.node_capacity == 2 * -(-CAP // 2)  # stage B: N=2 nodes
    hier42 = ForwardConfig(AXES, R, CAP, exchange="hierarchical", fast_size=2)
    assert hier42.peer_capacity == 2 * -(-CAP // 2)
    assert hier42.node_capacity == 2 * -(-CAP // 4)
    # explicit values always win
    explicit = ForwardConfig(
        AXES, R, CAP, exchange="hierarchical", fast_size=4,
        peer_capacity=7, node_capacity=11,
    )
    assert explicit.peer_capacity == 7 and explicit.node_capacity == 11

"""Rebalance tests (ISSUE 3): destination-preserving semantics + topology.

Covers the PR-3 bugfix — ``rebalance()`` must re-destinate ONLY resident
items (``dest == DISCARD``); pending items (``dest >= 0``) keep their
addressed destination and ride the same round — and the topology-aware
hierarchical plan: equalize within the fastest-axis group first, cross the
slower fabrics only with true surplus, and (``scope="intra"``) lower to a
program with ZERO payload bytes on any slower tier.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import DISCARD, ForwardConfig, WorkQueue, rebalance, work_item

R, CAP = 8, 64


@work_item
@dataclasses.dataclass
class Item:
    val: jax.Array
    src: jax.Array


def _run_rebalance(mesh, cfg, axes, count_of, dest_of, val_of, scope="global"):
    """Per-rank queue from the given builders; returns (counts, vals, srcs,
    total) gathered to the host."""

    def bal(_x):
        me = jax.lax.axis_index(axes)
        lane = jnp.arange(CAP, dtype=jnp.int32)
        n = count_of(me)
        q = WorkQueue(
            items=Item(val=val_of(me, lane), src=me * jnp.ones(CAP, jnp.int32)),
            dest=jnp.where(lane < n, dest_of(me, lane), DISCARD).astype(jnp.int32),
            count=n.astype(jnp.int32),
            drops=jnp.zeros((), jnp.int32),
        )
        nq, total = rebalance(q, cfg, scope=scope)
        return nq.count[None], nq.items.val, nq.items.src, total

    f = jax.jit(
        compat.shard_map(
            bal, mesh=mesh, in_specs=P(axes),
            out_specs=(P(axes), P(axes), P(axes), P()),
        )
    )
    counts, vals, srcs, total = f(jnp.arange(8.0))
    return (
        np.asarray(counts),
        np.asarray(vals).reshape(R, CAP),
        np.asarray(srcs).reshape(R, CAP),
        int(total),
    )


# ------------------------------------------- bugfix: pending dests preserved
def test_rebalance_preserves_pending_destinations(mesh8):
    """Regression for the clobbering bug: a mixed queue of pending
    (dest >= 0) and resident (dest == DISCARD) items.  Pending items MUST
    arrive where addressed; only residents get balanced."""
    cfg = ForwardConfig("data", R, CAP, exchange="padded")
    N_PEND = 5
    n_res_np = np.array([30, 0, 0, 0, 0, 0, 0, 0])
    n_res_j = jnp.asarray(n_res_np)

    counts, vals, srcs, total = _run_rebalance(
        mesh8, cfg, "data",
        count_of=lambda me: N_PEND + n_res_j[me],
        # lanes [0, N_PEND): pending, addressed to me+1; the rest resident
        dest_of=lambda me, k: jnp.where(k < N_PEND, (me + 1) % R, DISCARD),
        # val encodes provenance: pending = 1000 + me*100 + k, resident = 5000 + k
        val_of=lambda me, k: jnp.where(
            k < N_PEND, 1000.0 + me * 100.0 + k, 5000.0 + k
        ),
    )
    assert total == R * N_PEND + int(n_res_np.sum())
    res_target = -(-int(n_res_np.sum()) // R)  # ceil(30/8) == 4
    for r in range(R):
        got = vals[r][: counts[r]]
        pend = sorted(v for v in got if v < 5000)
        expect_pend = [1000.0 + ((r - 1) % R) * 100.0 + k for k in range(N_PEND)]
        assert pend == expect_pend, (
            f"rank {r}: pending items clobbered — got {pend}, want {expect_pend}"
        )
        n_res_here = int(counts[r]) - N_PEND
        assert 0 <= n_res_here <= res_target
    assert int(counts.sum()) - R * N_PEND == int(n_res_np.sum())


def test_rebalance_all_resident_unchanged_semantics(mesh8):
    """With no pending work the fix must not change the legacy behaviour:
    order-preserving ceil assignment over all ranks."""
    cfg = ForwardConfig("data", R, CAP, exchange="padded")
    n_j = jnp.asarray(np.array([40, 8, 0, 0, 0, 0, 0, 0]))
    counts, _v, _s, total = _run_rebalance(
        mesh8, cfg, "data",
        count_of=lambda me: n_j[me],
        dest_of=lambda me, k: jnp.full_like(k, DISCARD),
        val_of=lambda me, k: k.astype(jnp.float32),
    )
    assert total == 48
    assert counts.max() <= -(-48 // R) and counts.sum() == 48


# ------------------------------------- topology-aware hierarchical rebalance
def test_hierarchical_rebalance_node_local_skew_never_crosses_nodes(mesh_nodes24):
    """Skew confined within each node (node totals already balanced): the
    surplus/deficit plan must move NOTHING across the slow fabric — every
    received item's source rank sits in the receiver's node."""
    F = 4
    cfg = ForwardConfig(
        ("node", "device"), R, CAP, exchange="hierarchical", fast_size=F,
    )
    # lane 0 of each node holds everything: node totals equal (20 each)
    n_j = jnp.asarray(np.array([20, 0, 0, 0, 20, 0, 0, 0]))
    counts, _v, srcs, total = _run_rebalance(
        mesh_nodes24, cfg, ("node", "device"),
        count_of=lambda me: n_j[me],
        dest_of=lambda me, k: jnp.full_like(k, DISCARD),
        val_of=lambda me, k: k.astype(jnp.float32),
    )
    assert total == 40
    np.testing.assert_array_equal(counts.reshape(-1), [5] * R)
    for r in range(R):
        src_nodes = srcs[r][: counts[r]] // F
        assert (src_nodes == r // F).all(), (
            f"rank {r}: items crossed the slow fabric from nodes "
            f"{sorted(set(src_nodes.tolist()))}"
        )


def test_hierarchical_rebalance_moves_only_surplus_across_nodes(mesh_nodes24):
    """Cross-node skew: node 0 holds 40, node 1 none.  Quota = 20 per node,
    so EXACTLY the 20-item surplus crosses — node 0's keepers stay put."""
    F = 4
    cfg = ForwardConfig(
        ("node", "device"), R, CAP, exchange="hierarchical", fast_size=F,
    )
    n_j = jnp.asarray(np.array([10, 10, 10, 10, 0, 0, 0, 0]))
    counts, _v, srcs, total = _run_rebalance(
        mesh_nodes24, cfg, ("node", "device"),
        count_of=lambda me: n_j[me],
        dest_of=lambda me, k: jnp.full_like(k, DISCARD),
        val_of=lambda me, k: k.astype(jnp.float32),
    )
    assert total == 40
    np.testing.assert_array_equal(counts.reshape(-1), [5] * R)
    crossed = sum(
        int((srcs[r][: counts[r]] // F != r // F).sum()) for r in range(R)
    )
    assert crossed == 20, f"want exactly the surplus (20) to cross, got {crossed}"


def test_intra_scope_zero_slow_tier_payload_bytes(mesh_pods222):
    """The acceptance claim: scope='intra' rebalance of a node-local skew
    lowers to a program whose payload-sized collectives ALL bind to the
    fastest tier — zero payload bytes on tier 0, tier 1, or mixed patterns
    (asserted via the per-tier accounting of roofline.analysis) — and still
    equalises the skew within each group."""
    from repro.core import types as T
    from repro.roofline.analysis import per_tier_collective_bytes

    sizes = (2, 2, 2)
    axes = ("pod", "node", "device")
    cfg = ForwardConfig(
        axes, R, CAP, exchange="hierarchical", level_sizes=sizes,
    )

    def bal(_x):
        me = jax.lax.axis_index(axes)
        lane = jnp.arange(CAP, dtype=jnp.int32)
        n = jnp.where(me % 2 == 0, 12, 0)  # lane 0 of every group hoards
        q = WorkQueue(
            items=Item(val=lane.astype(jnp.float32), src=me * jnp.ones(CAP, jnp.int32)),
            dest=jnp.full((CAP,), DISCARD, jnp.int32),
            count=n.astype(jnp.int32),
            drops=jnp.zeros((), jnp.int32),
        )
        nq, total = rebalance(q, cfg, scope="intra")
        return nq.count[None], nq.items.src, total

    jitted = jax.jit(
        compat.shard_map(
            bal, mesh=mesh_pods222, in_specs=P(axes),
            out_specs=(P(axes), P(axes), P()),
        )
    )
    # --- per-tier accounting on the lowered HLO: zero slow payload bytes
    words = T.pack_spec(Item(val=jnp.zeros(()), src=jnp.zeros((), jnp.int32))).total_words
    threshold = min(cfg.level_capacities) * words * 4
    per_tier = per_tier_collective_bytes(
        jitted.lower(jnp.arange(8.0)).as_text(), sizes, min_bytes=threshold
    )
    assert per_tier[0] == 0 and per_tier[1] == 0 and per_tier["cross"] == 0, per_tier
    assert per_tier[2] > 0  # the intra-tier exchange is where the bytes go
    # --- and the node-local skew is fully corrected, intra-group
    counts, srcs, total = jitted(jnp.arange(8.0))
    counts = np.asarray(counts)
    srcs = np.asarray(srcs).reshape(R, CAP)
    assert int(total) == 4 * 12
    np.testing.assert_array_equal(counts.reshape(-1), [6] * R)
    F = sizes[-1]
    for r in range(R):
        assert (srcs[r][: counts[r]] // F == r // F).all()


def test_intra_scope_delivers_in_group_and_holds_cross_group_pending(mesh_nodes24):
    """Pending items through scope='intra': global dests inside the rank's
    fastest-axis group are delivered (rank space translated to lanes);
    cross-group pending cannot ride a fast-axis-only round and must stay in
    the holder's queue with their destination UNTOUCHED — never silently
    dropped or misrouted."""
    F = 4
    cfg = ForwardConfig(
        ("node", "device"), R, CAP, exchange="hierarchical", fast_size=F,
    )
    axes = ("node", "device")

    def bal(_x):
        me = jax.lax.axis_index(axes)
        lane = jnp.arange(CAP, dtype=jnp.int32)
        # each rank: 1 pending to the next lane IN its node, 1 pending to its
        # mirror rank in the OTHER node, 2 residents (skewed onto lane 0)
        in_group_dest = (me // F) * F + (me + 1) % F
        cross_dest = (me + F) % R
        n = jnp.where(me % F == 0, 4, 2)
        dest = jnp.select(
            [lane == 0, lane == 1],
            [in_group_dest, cross_dest],
            DISCARD,
        )
        dest = jnp.where(lane < n, dest, DISCARD)
        q = WorkQueue(
            items=Item(
                val=me * 100.0 + lane.astype(jnp.float32),
                src=me * jnp.ones(CAP, jnp.int32),
            ),
            dest=dest.astype(jnp.int32),
            count=n.astype(jnp.int32),
            drops=jnp.zeros((), jnp.int32),
        )
        nq, total = rebalance(q, cfg, scope="intra")
        return nq.count[None], nq.items.val, nq.dest, nq.drops[None], total

    f = jax.jit(
        compat.shard_map(
            bal, mesh=mesh_nodes24, in_specs=P(axes),
            out_specs=(P(axes), P(axes), P(axes), P(axes), P()),
        )
    )
    counts, vals, dests, drops, total = f(jnp.arange(8.0))
    counts = np.asarray(counts)
    vals = np.asarray(vals).reshape(R, CAP)
    dests = np.asarray(dests).reshape(R, CAP)
    # nothing lost: 8 in-group pending + 8 cross-group pending + 4 residents
    assert int(np.asarray(drops).sum()) == 0
    assert int(total) == 20 and int(counts.sum()) == 20
    for r in range(R):
        got = vals[r][: counts[r]].tolist()
        got_dest = dests[r][: counts[r]].tolist()
        # the in-group pending item addressed to me arrived (lane 0 of the
        # previous lane in my node), delivered → dest reset to DISCARD
        sender = (r // F) * F + (r - 1) % F
        assert sender * 100.0 + 0.0 in got, (r, got)
        # my cross-group pending item is still HERE, dest untouched
        held = [d for v, d in zip(got, got_dest) if v == r * 100.0 + 1.0]
        assert held == [(r + F) % R], (r, got, got_dest)


def test_intra_scope_rejects_flat_config(mesh8):
    cfg = ForwardConfig("data", R, CAP, exchange="padded")
    q = WorkQueue(
        items=Item(val=jnp.zeros(CAP), src=jnp.zeros(CAP, jnp.int32)),
        dest=jnp.full((CAP,), DISCARD, jnp.int32),
        count=jnp.zeros((), jnp.int32),
        drops=jnp.zeros((), jnp.int32),
    )
    with pytest.raises(ValueError, match="intra"):
        rebalance(q, cfg, scope="intra")


def test_rebalance_rejects_unknown_scope(mesh8):
    cfg = ForwardConfig("data", R, CAP, exchange="padded")
    q = WorkQueue(
        items=Item(val=jnp.zeros(CAP), src=jnp.zeros(CAP, jnp.int32)),
        dest=jnp.full((CAP,), DISCARD, jnp.int32),
        count=jnp.zeros((), jnp.int32),
        drops=jnp.zeros((), jnp.int32),
    )
    with pytest.raises(ValueError, match="scope"):
        rebalance(q, cfg, scope="bogus")


def test_hierarchical_rebalance_3level_equalizes(mesh_pods222):
    """Global topology-aware rebalance on a (2,2,2) mesh: heavy skew onto one
    rank ends within the ceil bound everywhere, conserving items."""
    sizes = (2, 2, 2)
    axes = ("pod", "node", "device")
    cfg = ForwardConfig(
        axes, R, CAP, exchange="hierarchical", level_sizes=sizes,
        level_capacities=(4 * CAP, 2 * CAP, CAP),  # ample: no stage clamps
    )
    n_j = jnp.asarray(np.array([41, 0, 0, 7, 0, 0, 0, 0]))
    counts, _v, _s, total = _run_rebalance(
        mesh_pods222, cfg, axes,
        count_of=lambda me: n_j[me],
        dest_of=lambda me, k: jnp.full_like(k, DISCARD),
        val_of=lambda me, k: k.astype(jnp.float32),
    )
    assert total == 48
    assert counts.sum() == 48
    assert counts.max() <= -(-48 // R)

"""Backpressure-law property tests (ISSUE 9): credit-based flow control.

The seventh invariant law — *no wire byte is spent on a row its receiver
cannot admit* — with graceful degradation under sustained overload.  The
load-bearing claims, each checked against independent evidence:

* **Credit is lossless where open flow collapses** — on the two overload
  shapes (fixed hot-pair saturation, full-width incast) open flow wastes
  >30% of its wire rows on receiver drops; credit flow delivers EVERY row
  with ZERO receiver drops, zero emission overflow, goodput exactly 1.0,
  and a first round that ships no payload (cold-start adverts only).
* **The device matches a numpy twin round-for-round** — delivered
  checksums, retained/age/receive traces, and round counts equal
  :func:`repro.chaos.simulate_flat_credit` exactly.  Not statistically
  close — the same trajectory.
* **Apportionment is exact and deterministic** — floor share plus
  rank-ordered residual sums to EXACTLY the advertised space for every
  free value, and the whole credit trajectory is bit-identical across
  marshal modes and shard counts; hierarchical routes (2- and 3-level)
  drain the same overload losslessly.
* **A zero-credit round ships zero payload rows** — a fully un-credited
  forward retains everything at the source, spends no wire on payload,
  and still advertises so the next round can move.
* **Overload accounting splits exactly** — under open flow every counted
  drop is EITHER an emission overflow at the source (the ``emit_overflow``
  counter, satellite 1) or a wasted wire row at the receiver:
  ``drops == emit_overflow + wasted_wire_rows``.  Under credit both terms
  are zero.
* **Recovery composes** — a credit drive preempted at a boundary and
  resumed from disk publishes byte-identical checkpoints (SHA-256 manifest
  digests over every carry leaf, credits included), and resuming a credit
  checkpoint under a different flow mode is refused.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import compat
from repro.chaos import (
    boundary_digests,
    expected_by_rank,
    incast_collapse,
    run_scenario,
    run_scenario_checkpointed,
    simulate_flat_credit,
    sustained_overload,
)
from repro.core import ForwardConfig, enqueue, forward_work, make_queue
from repro.core.recovery import resume_run
from repro.chaos.driver import _make_ctx

from helpers import Ray, ray_proto

pytestmark = pytest.mark.backpressure

P = jax.sharding.PartitionSpec

R = 8
_M32 = 1 << 32

# The pinned overload gauntlet: (scenario factory, queue capacity, slot S).
# Both configs make OPEN flow waste >30% of its wire rows at the receivers
# while CREDIT flow drains the identical schedule losslessly.
OVERLOAD = [
    (sustained_overload, 16, 4),
    (incast_collapse, 32, 8),
]
_IDS = ["sustained", "incast"]


def _run(mesh8, sc, cap, S, flow, **kw):
    return run_scenario(
        mesh8, sc, capacity=cap, max_rounds=256, peer_capacity=S,
        overflow="retain", flow=flow, **kw
    )


# ------------------------------------------------ graceful degradation gate
@pytest.mark.parametrize("factory,cap,S", OVERLOAD, ids=_IDS)
def test_credit_lossless_where_open_wastes_wire(mesh8, factory, cap, S):
    """The ISSUE 9 acceptance gate: where open flow sheds >30% of its wire
    rows, credit flow delivers everything — zero receiver drops, zero
    emission overflow, bounded occupancy — and its first round is
    advert-only (the zero-credit cold start risks no payload)."""
    sc = factory(R)
    open_res = _run(mesh8, sc, cap, S, "open")
    cred = _run(mesh8, sc, cap, S, "credit")

    # open flow collapses: real receiver drops, >30% of wire rows wasted
    assert open_res["drops"] > 0
    waste = open_res["wasted_wire_rows"] / open_res["wire_rows"]
    assert waste > 0.30, f"open waste {waste:.2f} too mild to gate on"
    assert open_res["goodput"] < 0.9

    # credit degrades gracefully on the identical schedule
    np.testing.assert_array_equal(cred["delivered"], expected_by_rank(sc))
    assert cred["delivered_total"] == sc.emitted
    assert cred["drops"] == 0 and cred["lost"] == 0 and cred["done"]
    assert cred["emit_overflow"] == 0
    assert cred["goodput"] == 1.0 and cred["wasted_wire_rows"] == 0
    # cold start: round 0 carries adverts only, no payload rows
    assert int(np.asarray(cred["recv_trace"])[0]) == 0
    # bounded queues: the backlog parks at sources, no queue ever overfills
    assert int(np.asarray(cred["retained_trace"]).max()) <= R * cap
    # the price of losslessness is TIME, not loss
    assert cred["rounds"] > open_res["rounds"]


@pytest.mark.parametrize("factory,cap,S", OVERLOAD, ids=_IDS)
def test_credit_matches_numpy_twin(mesh8, factory, cap, S):
    """The device credit trajectory equals the host-side simulator exactly:
    delivered checksums, round count, and the retained/age/receive traces,
    round for round."""
    sc = factory(R)
    dev = _run(mesh8, sc, cap, S, "credit")
    tw = simulate_flat_credit(sc, peer_capacity=S, capacity=cap, max_rounds=256)
    assert dev["rounds"] == tw["rounds"] and tw["done"]
    np.testing.assert_array_equal(dev["delivered"], tw["delivered"])
    for k in ("retained_trace", "age_trace", "recv_trace"):
        np.testing.assert_array_equal(
            np.asarray(dev[k]), np.asarray(tw[k]), err_msg=k
        )


def test_open_overload_baseline_pinned(mesh8):
    """The livelock baseline this PR measures credit against (satellite 2):
    open flow on the hot-pair saturation schedule, numbers pinned per rank.
    The hot pair hoards the deliveries while the cold ranks starve, nearly
    half the wire is spent on rows the receivers throw away, and the books
    still balance (counted loss, not silent loss)."""
    sc = sustained_overload(R)
    res = _run(mesh8, sc, 16, 4, "open")
    assert res["delivered_total"] == 534 and res["rounds"] == 15
    assert res["delivered"][:, 0].tolist() == [159, 143, 36, 29, 39, 44, 41, 43]
    assert res["drops"] == 618 and res["lost"] == 0 and res["done"]
    assert res["emit_overflow"] == 169
    assert res["wire_rows"] == 983 and res["wasted_wire_rows"] == 449
    assert abs(res["goodput"] - (1 - 449 / 983)) < 1e-9


@pytest.mark.parametrize("factory,cap,S", OVERLOAD, ids=_IDS)
def test_drop_ledger_splits_into_emit_and_wire(mesh8, factory, cap, S):
    """Satellite 1: local emission overflow in retain mode surfaces as its
    own ``emit_overflow`` counter, distinct from receiver-side waste — the
    two must add up to EXACTLY the counted drops under open flow, and
    credit+retain drives both to zero."""
    sc = factory(R)
    open_res = _run(mesh8, sc, cap, S, "open")
    assert (
        open_res["drops"]
        == open_res["emit_overflow"] + open_res["wasted_wire_rows"]
    )
    cred = _run(mesh8, sc, cap, S, "credit")
    assert cred["emit_overflow"] == 0 and cred["wasted_wire_rows"] == 0
    assert cred["drops"] == 0


# ------------------------------------------------- apportionment properties
def _grants(free, num_ranks):
    """The CreditGate law, host-side: rank me's grant toward a destination
    advertising ``free`` rows."""
    f = max(int(free), 0)
    return [f // num_ranks + (me < f % num_ranks) for me in range(num_ranks)]


def test_grants_sum_exactly_to_advertised_free():
    """Floor share + rank-ordered residual: the grants over all R senders
    sum to EXACTLY the advertised space — never more (no overshoot), never
    less (no stranded credit) — for every free value including negatives
    (in-flight debt clips to zero)."""
    for Rn in (2, 3, 8, 16):
        for free in list(range(-3, 3 * Rn + 2)) + [10**6, 10**6 + Rn - 1]:
            g = _grants(free, Rn)
            assert sum(g) == max(free, 0)
            assert max(g) - min(g) <= 1  # fair to within one row
            assert g == sorted(g, reverse=True)  # residual is rank-ordered


def test_credit_trajectory_deterministic_across_modes(mesh8):
    """Satellite 3: the whole credit trajectory — deliveries, rounds,
    retained trace — is bit-identical across marshal modes and shard
    counts.  Apportionment is collective-free and replicated, so HOW the
    rows are marshalled cannot change WHAT ships."""
    sc = sustained_overload(R)
    ref = _run(mesh8, sc, 32, 8, "credit", marshal="sort")
    for kw in (dict(marshal="scatter"), dict(marshal="sort", pipeline_shards=2)):
        alt = _run(mesh8, sc, 32, 8, "credit", **kw)
        np.testing.assert_array_equal(alt["delivered"], ref["delivered"])
        assert alt["rounds"] == ref["rounds"]
        np.testing.assert_array_equal(
            np.asarray(alt["retained_trace"]), np.asarray(ref["retained_trace"])
        )


HIER = [
    ("mesh_nodes24", ("node", "device"), (8, 8)),
    ("mesh_pods222", ("pod", "node", "device"), (8, 8, 8)),
]


@pytest.mark.parametrize("fixture,axes,caps", HIER, ids=["2level", "3level"])
def test_hierarchical_credit_drains_overload(request, fixture, axes, caps):
    """Tiered credit relay: the same hot-pair overload through 2- and
    3-level routes drains to the exact delivery checksums with zero drops —
    per-tier adverts aggregate along the route and gate the first clamp."""
    mesh = request.getfixturevalue(fixture)
    sc = sustained_overload(R)
    res = run_scenario(
        mesh, sc, capacity=256, max_rounds=512, axis_name=axes,
        exchange="hierarchical", level_capacities=caps,
        overflow="retain", flow="credit",
    )
    np.testing.assert_array_equal(res["delivered"], expected_by_rank(sc))
    assert res["drops"] == 0 and res["lost"] == 0 and res["done"]


CAP = 64


def test_zero_credit_round_ships_no_payload(mesh8):
    """An all-zero credit vector retains EVERYTHING at the source: zero
    payload rows arrive anywhere, nothing is dropped, and the round still
    advertises fresh credits so the next round can move the backlog."""
    cfg = ForwardConfig(
        "data", R, CAP, overflow="retain", flow="credit", telemetry=True
    )

    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index("data")
        n = 10
        k = jnp.arange(n)
        rays = Ray(
            origin=jnp.ones((n, 3)) * me,
            direction=jnp.zeros((n, 3)),
            tmin=k.astype(jnp.float32),
            pixel=(k + me * 100).astype(jnp.int32),
            integral=jnp.zeros(n),
        )
        dest = ((me + 1 + k) % R).astype(jnp.int32)  # all rows off-rank
        q = enqueue(q, rays, dest, jnp.ones(n, bool))
        nq, total, age, credits_out, stats = forward_work(
            q, cfg, credits=jnp.zeros((R,), jnp.int32)
        )
        return (
            nq.count[None], total, nq.drops[None],
            stats.recv_total[None], credits_out[None], age[None],
        )

    f = jax.jit(
        compat.shard_map(
            kernel, mesh=mesh8, in_specs=P("data"),
            out_specs=(P("data"), P(), P("data"), P("data"), P("data"), P("data")),
        )
    )
    counts, total, drops, recv, credits_out, age = f(jnp.arange(8.0))
    assert int(total) == 80  # termination cannot fire with held work
    np.testing.assert_array_equal(np.asarray(counts), np.full(R, 10))
    assert np.asarray(drops).sum() == 0
    np.testing.assert_array_equal(np.asarray(recv), np.zeros(R))  # no payload
    # every rank's fresh advert opens room for the NEXT round
    assert (np.asarray(credits_out) > 0).all()
    # the held rows aged one round
    assert (np.asarray(age).reshape(R, CAP)[:, :10] == 1).all()


def test_credit_requires_retain_and_padded():
    """Config validation: credit flow needs the retain spill path to park
    un-credited tails, and the onehot exchange has no widened count
    collective to ride."""
    with pytest.raises(ValueError):
        ForwardConfig("data", R, CAP, overflow="drop", flow="credit")
    with pytest.raises(ValueError):
        ForwardConfig(
            "data", R, CAP, exchange="onehot", overflow="retain", flow="credit"
        )
    with pytest.raises(ValueError):
        ForwardConfig("data", R, CAP, flow="closed")  # unknown mode
    with pytest.raises(ValueError):
        ForwardConfig(
            "data", R, CAP, overflow="retain", flow="credit", emit_reserve=CAP
        )


# ------------------------------------------------------- recovery composes
def test_preempt_resume_credit_bitexact(tmp_path, mesh8):
    """The recovery law composes with backpressure: a credit drive killed at
    a boundary and resumed from disk re-publishes byte-identical checkpoints
    (the carried credit vector is part of the manifest) and lands on the
    uninterrupted run's exact trajectory."""
    sc = sustained_overload(R)
    kw = dict(
        capacity=16, peer_capacity=4, overflow="retain", flow="credit",
        max_rounds=256,
    )
    ref = run_scenario(mesh8, sc, **kw)
    a = run_scenario_checkpointed(
        mesh8, sc, ckpt_dir=tmp_path / "a", checkpoint_every=8, keep=99, **kw
    )
    b = run_scenario_checkpointed(
        mesh8, sc, ckpt_dir=tmp_path / "b", checkpoint_every=8, keep=99,
        preempt_at=20, **kw
    )
    assert b["preempted"] and not a["preempted"]
    np.testing.assert_array_equal(a["delivered"], ref["delivered"])
    np.testing.assert_array_equal(b["delivered"], ref["delivered"])
    assert a["rounds"] == b["rounds"] == ref["rounds"]
    assert a["lost"] == b["lost"] == 0 and a["drops"] == b["drops"] == 0
    da, db = boundary_digests(tmp_path / "a"), boundary_digests(tmp_path / "b")
    common = sorted(set(da) & set(db))
    assert len(common) >= 3
    for step in common:
        assert da[step] == db[step], f"state diverged at boundary {step}"


def test_resume_refuses_flow_mismatch(tmp_path, mesh8):
    """A checkpoint saved under credit flow names its flow mode in the meta;
    resuming it with an open-flow context must be refused, not silently
    reinterpreted (the carry shapes differ — credits are a carried leaf)."""
    sc = sustained_overload(R)
    run_scenario_checkpointed(
        mesh8, sc, capacity=16, peer_capacity=4, overflow="retain",
        flow="credit", max_rounds=256, ckpt_dir=tmp_path, checkpoint_every=8,
        keep=99,
    )
    ctx = _make_ctx(
        mesh8, capacity=16, peer_capacity=4, overflow="retain", flow="open",
        max_rounds=256,
    )
    aux_like = tuple(np.zeros((R,), np.uint32) for _ in range(3))
    with pytest.raises(ValueError, match="flow"):
        resume_run(
            ctx, lambda q, aux, rnd: (q, aux), tmp_path,
            aux_specs=(ctx._spec,) * 3, aux_like=aux_like,
        )

"""Tests for the packed wire format (§4.2 "large contiguous blocks").

Two families:

  * ``pack_payload ∘ unpack_payload`` is the identity, bit-for-bit, for any
    mixed-dtype work-item pytree (property-tested) — the JAX rendering of
    the paper's trivially-copyable ``RayT`` contract;
  * the packed-path ``forward_work`` is bit-exact against the ``onehot``
    all-gather oracle for every executable backend, including the fused
    Pallas marshal path (``use_pallas=True``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis — deterministic stub
    from _hypothesis_stub import given, settings, st

from repro import compat
from repro.core import ForwardConfig, enqueue, forward_work, make_queue, work_item
from repro.core import types as T

from helpers import Ray, make_rays, ray_proto

R, CAP = 8, 64


# ------------------------------------------------------- pack/unpack identity
@given(
    st.integers(1, 33),  # batch
    st.integers(1, 5),   # f32 vector width
    st.integers(0, 3),   # number of extra scalar i32 fields
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_pack_unpack_identity_mixed_f32_i32(n, width, extra, seed):
    rng = np.random.default_rng(seed)
    items = {
        "vec": jnp.asarray(rng.normal(size=(n, width)).astype(np.float32)),
        "idx": jnp.asarray(rng.integers(-(2**31), 2**31 - 1, n, dtype=np.int32)),
    }
    for i in range(extra):
        items[f"s{i}"] = jnp.asarray(
            rng.integers(0, 1000, n, dtype=np.int32)
        )
    packed, spec = T.pack_payload(items)
    assert packed.dtype == jnp.uint32
    assert packed.shape == (n, spec.total_words)
    back = T.unpack_payload(packed, spec)
    assert jax.tree.structure(back) == jax.tree.structure(items)
    for k in items:
        assert back[k].dtype == items[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(items[k]))


def test_pack_unpack_identity_subword_and_bool():
    """Sub-word dtypes ride zero-padded word slots and round-trip exactly."""
    n = 17
    rng = np.random.default_rng(3)
    items = {
        "h": jnp.asarray(rng.integers(-(2**15), 2**15 - 1, (n, 5), dtype=np.int16)),
        "b": jnp.asarray(rng.random(n) < 0.5),
        "x": jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32)),
    }
    packed, spec = T.pack_payload(items)
    back = T.unpack_payload(packed, spec)
    for k in items:
        assert back[k].dtype == items[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(items[k]))


def test_pack_unpack_zero_size_leaf():
    """Zero-size leaves occupy zero wire words and round-trip (an item type
    with an empty field must still forward)."""
    n = 9
    items = {
        "empty": jnp.zeros((n, 0), jnp.float32),
        "x": jnp.arange(n, dtype=jnp.int32),
    }
    packed, spec = T.pack_payload(items)
    assert spec.words == (0, 1) and packed.shape == (n, 1)
    back = T.unpack_payload(packed, spec)
    assert back["empty"].shape == (n, 0) and back["empty"].dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(items["x"]))


def test_pack_payload_preserves_exact_float_bits():
    """NaN payloads, signed zeros and denormals must survive the wire —
    pack is a bitcast, not a value conversion."""
    vals = np.array(
        [np.nan, -np.nan, 0.0, -0.0, np.inf, -np.inf, 1e-45, -1e-45], np.float32
    )
    items = {"v": jnp.asarray(vals)}
    packed, spec = T.pack_payload(items)
    back = np.asarray(T.unpack_payload(packed, spec)["v"])
    np.testing.assert_array_equal(back.view(np.uint32), vals.view(np.uint32))


def test_pack_spec_matches_item_nbytes():
    """A word-aligned item packs to exactly item_nbytes of wire (44-byte Fig-8
    ray → 11 words)."""
    spec = T.pack_spec(ray_proto())
    assert spec.total_words * 4 == T.item_nbytes(ray_proto()) == 36
    assert spec.offsets == (0, 3, 6, 7, 8)


# --------------------------------------------- packed path vs onehot oracle
def _run(mesh8, cfg, dest_of):
    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index("data")
        n = 10
        k = jnp.arange(n)
        rays = Ray(
            origin=jnp.ones((n, 3)) * me,
            direction=jnp.zeros((n, 3)),
            tmin=k.astype(jnp.float32),
            pixel=(k + me * 100).astype(jnp.int32),
            integral=jnp.zeros(n),
        )
        q = enqueue(q, rays, dest_of(me, k).astype(jnp.int32), jnp.ones(n, bool))
        nq, total = forward_work(q, cfg)
        return nq.count[None], nq.items.pixel, nq.items.origin, nq.items.tmin

    f = jax.jit(
        compat.shard_map(
            kernel, mesh=mesh8, in_specs=P("data"),
            out_specs=(P("data"), P("data"), P("data"), P("data")),
        )
    )
    counts, pixels, origins, tmins = f(jnp.arange(8.0))
    return (
        np.asarray(counts),
        np.asarray(pixels).reshape(R, CAP),
        np.asarray(origins).reshape(R, CAP, 3),
        np.asarray(tmins).reshape(R, CAP),
    )


_BACKENDS = [
    pytest.param("padded", False, id="padded"),
    pytest.param("padded", True, id="padded-pallas"),
    pytest.param(
        "ragged", False, id="ragged",
        marks=pytest.mark.skipif(
            not compat.HAS_RAGGED_ALL_TO_ALL,
            reason="installed JAX has no lax.ragged_all_to_all",
        ),
    ),
]


@pytest.mark.parametrize("exchange,use_pallas", _BACKENDS)
def test_packed_forward_bitexact_vs_onehot(mesh8, exchange, use_pallas):
    if exchange == "ragged" and jax.default_backend() == "cpu":
        pytest.skip("XLA:CPU cannot execute ragged_all_to_all")
    dest_of = lambda me, k: (me * 5 + k * 3) % R
    got = _run(
        mesh8,
        ForwardConfig("data", R, CAP, exchange=exchange, use_pallas=use_pallas),
        dest_of,
    )
    want = _run(mesh8, ForwardConfig("data", R, CAP, exchange="onehot"), dest_of)
    np.testing.assert_array_equal(got[0], want[0])
    for r in range(R):  # valid prefixes identical (both stable); tails garbage
        n = got[0][r]
        np.testing.assert_array_equal(got[1][r][:n], want[1][r][:n])
        np.testing.assert_array_equal(got[2][r][:n], want[2][r][:n])
        # float payload must be BIT-exact, not just allclose: the wire is a
        # bitcast, forwarding may not perturb a single mantissa bit
        np.testing.assert_array_equal(
            got[3][r][:n].view(np.uint32), want[3][r][:n].view(np.uint32)
        )


def test_packed_forward_multi_leaf_dtypes(mesh8):
    """A work item with i32 + f32 + wide vector leaves forwards exactly
    (the single packed collective carries all of them)."""

    @work_item
    @dataclasses.dataclass
    class Fat:
        mat: jax.Array   # (2, 3) f32
        tag: jax.Array   # () i32

    def proto():
        return Fat(mat=jnp.zeros((2, 3)), tag=jnp.zeros((), jnp.int32))

    cfg = ForwardConfig("data", R, CAP, exchange="padded")

    def kernel(_x):
        q = make_queue(proto(), CAP)
        me = jax.lax.axis_index("data")
        n = 6
        items = Fat(
            mat=jnp.arange(n * 6, dtype=jnp.float32).reshape(n, 2, 3) + me * 1000,
            tag=(jnp.arange(n) + me * 100).astype(jnp.int32),
        )
        dest = ((me + jnp.arange(n)) % R).astype(jnp.int32)
        q = enqueue(q, items, dest, jnp.ones(n, bool))
        nq, total = forward_work(q, cfg)
        return nq.count[None], nq.items.tag, nq.items.mat, total

    f = jax.jit(
        compat.shard_map(
            kernel, mesh=mesh8, in_specs=P("data"),
            out_specs=(P("data"), P("data"), P("data"), P()),
        )
    )
    counts, tags, mats, total = f(jnp.arange(8.0))
    counts = np.asarray(counts)
    tags = np.asarray(tags).reshape(R, CAP)
    mats = np.asarray(mats).reshape(R, CAP, 2, 3)
    assert int(total) == 8 * 6 and counts.sum() == 48
    for r in range(R):
        for i in range(counts[r]):
            src, k = divmod(int(tags[r, i]), 100)
            assert (src + k) % R == r  # addressed here
            np.testing.assert_array_equal(
                mats[r, i],
                np.arange(k * 6, k * 6 + 6, dtype=np.float32).reshape(2, 3)
                + src * 1000,
            )

"""Shared test fixtures.

Tests that exercise collectives need a real multi-device mesh, so we ask the
CPU platform for 8 devices — enough for an interesting (2, 4) mesh.  The
production 512-device setting lives ONLY in ``repro.launch.dryrun`` (the
dry-run harness), never here: smoke tests and benchmarks are written to work
at whatever small device count this gives.

All version-sensitive JAX surface (``AxisType``, ``jax.shard_map``,
``ragged_all_to_all``) is reached through ``repro.compat`` — tests that need
a feature the installed JAX lacks must ``pytest.skip`` on the ``HAS_*``
flags, never fail at import.
"""
import os

# Must run before jax locks the backend on first init.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pytest

from repro import compat


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "pallas_interpret: force Pallas kernels into interpret mode for this "
        "test (sets RAFI_PALLAS_INTERPRET=1) so tier-1 exercises the kernel "
        "code paths — bucket_scatter, sort_keys, marshal — without a TPU.  "
        "On the CPU container interpret is already the default; on a TPU "
        "runner the marker keeps these tests backend-independent.",
    )
    config.addinivalue_line(
        "markers",
        "telemetry: exercises the ISSUE-5 traffic-telemetry / adaptive-"
        "capacity subsystem (repro.telemetry + repro.tune).  CI can select "
        "the subsystem with `-m telemetry`; the collective-budget guard "
        "(telemetry adds zero payload-sized collectives) carries the marker "
        "too so the selection is self-contained.",
    )
    config.addinivalue_line(
        "markers",
        "chaos: drives the ISSUE-6 deterministic fault-injection harness "
        "(repro.chaos) through the real on-device loop — multi-round, "
        "multi-scenario property tests of the lossless law (retain mode "
        "loses nothing) and the conservation identity (drop mode counts "
        "every loss).  Part of tier-1; CI can select with `-m chaos`.",
    )
    config.addinivalue_line(
        "markers",
        "recovery: exercises the ISSUE-7 recovery law — checkpoint/resume of "
        "the segmented drive loop (repro.core.recovery + repro.ckpt), "
        "elastic R→R′ restore, health-aware rank draining, and the "
        "conservation watchdog.  Part of tier-1; CI can select with "
        "`-m recovery`.",
    )
    config.addinivalue_line(
        "markers",
        "backpressure: exercises the ISSUE-9 backpressure law — credit-based "
        "flow control (``ForwardConfig.flow='credit'``): widened count "
        "collectives carrying receiver adverts, deterministic floor-share "
        "credit apportionment, the drive's emission gate, and graceful "
        "degradation under sustained overload (bounded occupancy, zero "
        "receiver drops where open flow wastes wire).  Part of tier-1; CI "
        "can select with `-m backpressure`.",
    )
    config.addinivalue_line(
        "markers",
        "obs: exercises the ISSUE-10 observation law (repro.obs) — host-side "
        "span tracing, metrics export, and the flight-data analyzer.  The "
        "marker also turns the ambient tracer ON via RAFI_TRACE=1 (the env "
        "toggle mirroring RAFI_PALLAS_INTERPRET), so marked tests run every "
        "drive entry point with its trace hooks live.  Part of tier-1; CI "
        "can select with `-m obs`.",
    )
    config.addinivalue_line(
        "markers",
        "slow: multi-minute end-to-end runs (the quickstart subprocess "
        "smoke test).  Part of tier-1; deselect locally with `-m 'not slow'` "
        "when iterating.",
    )
    config.addinivalue_line(
        "markers",
        "pipeline: exercises the ISSUE-8 overlap law — micro-shard pipelined "
        "forwarding (``ForwardConfig.pipeline_shards``) built on the stage-"
        "graph exchange layer (repro.core.stages).  Placement must stay "
        "bit-exact vs the bulk round and the per-axis collective budget "
        "scales to S payload + S count collectives.  Part of tier-1; CI can "
        "select with `-m pipeline`.",
    )


@pytest.fixture(autouse=True)
def _pallas_interpret_toggle(request, monkeypatch):
    """Honour the ``pallas_interpret`` marker via the env var that
    ``repro.kernels.default_interpret`` consults (the CI toggle)."""
    if request.node.get_closest_marker("pallas_interpret"):
        monkeypatch.setenv("RAFI_PALLAS_INTERPRET", "1")


@pytest.fixture(autouse=True)
def _rafi_trace_toggle(request, monkeypatch):
    """Honour the ``obs`` marker via the ``RAFI_TRACE`` env toggle that
    ``repro.obs.trace`` consults lazily (mirrors ``RAFI_PALLAS_INTERPRET``):
    marked tests run with the ambient tracer installed; teardown uninstalls
    it and restores the lazy env check so other tests stay untraced."""
    if not request.node.get_closest_marker("obs"):
        yield
        return
    from repro.obs import trace as OT

    monkeypatch.setenv(OT.ENV_VAR, "1")
    monkeypatch.setattr(OT, "_ENV_CHECKED", False)
    yield
    OT.uninstall()


@pytest.fixture(scope="session")
def mesh8():
    """A 1-D 8-way mesh over axis 'data'."""
    return compat.make_mesh((8,), ("data",))


@pytest.fixture(scope="session")
def mesh24():
    """A 2-D (2, 4) mesh over ('data', 'model') — miniature of the pod mesh."""
    return compat.make_mesh((2, 4), ("data", "model"))


@pytest.fixture(scope="session")
def mesh_nodes24():
    """A 2-D (node=2, device=4) forwarding mesh — the hierarchical exchange's
    (slow, fast) shape."""
    from repro.launch.mesh import make_node_mesh

    return make_node_mesh(2, 4)


@pytest.fixture(scope="session")
def mesh_nodes42():
    """The transposed (node=4, device=2) forwarding mesh."""
    from repro.launch.mesh import make_node_mesh

    return make_node_mesh(4, 2)


@pytest.fixture(scope="session")
def mesh_pods222():
    """A 3-D (pod=2, node=2, device=2) forwarding mesh — the N-level
    exchange's (slowest, …, fastest) shape."""
    from repro.launch.mesh import make_pod_mesh

    return make_pod_mesh(2, 2, 2)

"""Shared test fixtures.

Tests that exercise collectives need a real multi-device mesh, so we ask the
CPU platform for 8 devices — enough for an interesting (2, 4) mesh.  The
production 512-device setting lives ONLY in ``repro.launch.dryrun`` (the
dry-run harness), never here: smoke tests and benchmarks are written to work
at whatever small device count this gives.
"""
import os

# Must run before jax locks the backend on first init.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import pytest
from jax.sharding import AxisType


@pytest.fixture(scope="session")
def mesh8():
    """A 1-D 8-way mesh over axis 'data'."""
    return jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))


@pytest.fixture(scope="session")
def mesh24():
    """A 2-D (2, 4) mesh over ('data', 'model') — miniature of the pod mesh."""
    return jax.make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,) * 2)

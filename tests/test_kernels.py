"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes (and dtypes where the kernel is dtype-generic)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis — deterministic stub
    from _hypothesis_stub import given, settings, st

from repro.core import work_item
from repro.kernels.bucket_scatter import (
    kernel as bs_kernel,
    ops as bs_ops,
    ref as bs_ref,
)
from repro.kernels.compact import ops as compact_ops, ref as compact_ref
from repro.kernels.delta_tracking import ops as dt_ops, ref as dt_ref
from repro.kernels.marshal import ops as marshal_ops, kernel as marshal_k, ref as marshal_ref
from repro.kernels.nbody_forces import ops as nb_ops, ref as nb_ref
from repro.kernels.rk4_advect import ops as rk4_ops, ref as rk4_ref
from repro.kernels.sort_keys import kernel as sk_kernel, ops as sk_ops, ref as sk_ref


# ---------------------------------------------------------------- sort_keys
@pytest.mark.parametrize("cap,tile", [(64, 16), (256, 256), (1024, 128), (96, 32)])
@pytest.mark.parametrize("num_ranks", [4, 8, 64])
def test_sort_keys_pack_hist_matches_ref(cap, tile, num_ranks):
    rng = np.random.default_rng(cap + num_ranks)
    dest = jnp.array(rng.integers(-2, num_ranks + 1, cap), jnp.int32)
    count = jnp.int32(rng.integers(0, cap + 1))
    ib = max(1, (cap - 1).bit_length())
    keys, hist = sk_kernel.pack_and_histogram(
        dest, count, num_ranks=num_ranks, idx_bits=ib, tile=tile, interpret=True
    )
    rkeys, rhist = sk_ref.pack_and_histogram(dest, count, num_ranks=num_ranks, idx_bits=ib)
    np.testing.assert_array_equal(np.asarray(keys), np.asarray(rkeys))
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(rhist))


def test_sort_keys_full_sort_matches_core():
    from repro.core import sorting as S

    @work_item
    @dataclasses.dataclass
    class Item:
        a: jax.Array
        b: jax.Array

    cap, R = 256, 16
    rng = np.random.default_rng(7)
    items = Item(
        a=jnp.array(rng.normal(size=(cap, 4)), jnp.float32),
        b=jnp.array(rng.integers(0, 100, cap), jnp.int32),
    )
    dest = jnp.array(rng.integers(-1, R, cap), jnp.int32)
    count = jnp.int32(200)
    pi, pd, pc = sk_ops.sort_by_destination(items, dest, count, R, interpret=True)
    ri, rd, rc = S.sort_by_destination(items, dest, count, R, method="pack")
    np.testing.assert_array_equal(np.asarray(pd), np.asarray(rd))
    np.testing.assert_array_equal(np.asarray(pc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(pi.b), np.asarray(ri.b))
    np.testing.assert_allclose(np.asarray(pi.a), np.asarray(ri.a))


# ----------------------------------------------------------- bucket_scatter
@pytest.mark.pallas_interpret
@pytest.mark.parametrize(
    "cap,tile", [(64, 16), (256, 256), (96, 32), (192, 64), (128, 128)]
)
@pytest.mark.parametrize("num_ranks", [4, 8, 64])
def test_bucket_scatter_rank_hist_matches_ref(cap, tile, num_ranks):
    """The chunked-MXU prefix kernel vs the one-hot cumsum oracle — d_clean,
    in-bucket rank, and histogram all bit-equal (incl. non-128-multiple tiles
    that exercise the gcd chunking)."""
    rng = np.random.default_rng(cap + num_ranks)
    dest = jnp.array(rng.integers(-2, num_ranks + 2, cap), jnp.int32)
    count = jnp.int32(rng.integers(0, cap + 1))
    dk, rk, hk = bs_kernel.rank_and_histogram(
        dest, count, num_ranks=num_ranks, tile=tile, interpret=True
    )
    dr, rr, hr = bs_ref.rank_and_histogram(dest, count, num_ranks=num_ranks)
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(hk), np.asarray(hr))


@pytest.mark.pallas_interpret
@pytest.mark.parametrize("n,slots,D", [(64, 64, 3), (256, 80, 9), (100, 64, 1)])
def test_bucket_scatter_rows_matches_ref(n, slots, D):
    """scatter_rows vs its jnp oracle, incl. out-of-range (dropped) rows and
    duplicate trash positions."""
    rng = np.random.default_rng(n + slots)
    src = jnp.array(rng.integers(0, 2**32, (n, D), dtype=np.uint32))
    pos = jnp.array(rng.integers(-3, slots + 3, n), jnp.int32)
    got = bs_kernel.scatter_rows(src, pos, num_slots=slots, interpret=True)
    want = bs_ref.scatter_rows(src, pos, num_slots=slots)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.pallas_interpret
def test_bucket_scatter_negative_positions_are_dropped():
    """Negative dstpos must land in the trash, not wrap to a valid slot
    (``.at[].set`` wraps negatives even with mode='drop' — the ref guards
    explicitly, the kernel redirects them past the end)."""
    src = jnp.ones((4, 2), jnp.uint32)
    pos = jnp.array([-1, -4, 1, 9], jnp.int32)  # only index 2 survives
    want = jnp.zeros((4, 2), jnp.uint32).at[1].set(1)
    got_k = bs_kernel.scatter_rows(src, pos, num_slots=4, interpret=True)
    got_r = bs_ref.scatter_rows(src, pos, num_slots=4)
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_r), np.asarray(want))


def test_bucket_scatter_rejects_f32_inexact_capacity():
    """Counts ride the MXU in f32: capacities past 2**24 must raise loudly
    (the scatter analogue of pack_keys' 32-bit overflow), never collide."""
    with pytest.raises(ValueError, match="2\\*\\*24"):
        bs_kernel.rank_and_histogram(
            jnp.zeros((1 << 25,), jnp.int32), jnp.int32(0), num_ranks=4,
            interpret=True,
        )


@pytest.mark.pallas_interpret
def test_bucket_scatter_reproduces_sort_placement():
    """The tentpole equivalence at the kernel level: scattering every row to
    ``off[dest] + rank`` reproduces key-pack + lax.sort + gather bit-exactly
    on the valid prefix — the counting sort IS the stable sort."""
    from repro.core import sorting as S

    cap, R, W = 256, 16, 7
    rng = np.random.default_rng(21)
    dest = jnp.array(rng.integers(-1, R + 1, cap), jnp.int32)
    count = jnp.int32(200)
    packed = jnp.array(rng.integers(0, 2**32, (cap, W), dtype=np.uint32))
    d_clean, rank, hist = bs_ops.rank_and_histogram(
        dest, count, num_ranks=R, interpret=True
    )
    off = jnp.cumsum(hist[:R]) - hist[:R]
    keep = d_clean < R
    dstpos = jnp.where(keep, off[jnp.clip(d_clean, 0, R - 1)] + rank, cap)
    got = bs_ops.scatter_rows(packed, dstpos, num_slots=cap, interpret=True)
    perm, _d, counts = S.sort_permutation(dest, count, R, method="pack")
    want = jnp.take(packed, perm, axis=0)
    n_valid = int(np.asarray(hist[:R]).sum())
    np.testing.assert_array_equal(
        np.asarray(got)[:n_valid], np.asarray(want)[:n_valid]
    )
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(counts))


# ------------------------------------------------------------------ compact
@pytest.mark.parametrize("cap,tile", [(32, 8), (512, 128), (2048, 2048), (48, 16)])
def test_compact_positions_matches_ref(cap, tile):
    rng = np.random.default_rng(cap)
    mask = jnp.array(rng.random(cap) < 0.4)
    pos, total = compact_ops.K.compact_positions(mask, tile=tile, interpret=True)
    rpos, rtotal = compact_ref.compact_positions(mask)
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(rpos))
    assert int(total[0]) == int(rtotal[0])


@given(st.lists(st.booleans(), min_size=1, max_size=64))
@settings(max_examples=30, deadline=None)
def test_compact_positions_property(bits):
    n = 64
    mask = jnp.zeros(n, bool).at[: len(bits)].set(jnp.array(bits))
    pos, total = compact_ops.compact_positions(mask)
    m = np.asarray(mask)
    p = np.asarray(pos)[m]
    assert int(total) == m.sum()
    # emitted positions are exactly 0..k-1 in lane order (stable append)
    np.testing.assert_array_equal(p, np.arange(m.sum()))


def test_compact_scatter_roundtrip():
    @work_item
    @dataclasses.dataclass
    class V:
        x: jax.Array

    n = 128
    rng = np.random.default_rng(3)
    items = V(x=jnp.array(rng.normal(size=(n, 2)), jnp.float32))
    mask = jnp.array(rng.random(n) < 0.3)
    out, count = compact_ops.compact(items, mask, 64)
    m = np.asarray(mask)
    np.testing.assert_allclose(
        np.asarray(out.x)[: int(count)], np.asarray(items.x)[m][:64]
    )


# ------------------------------------------------------------------ marshal
@pytest.mark.parametrize("cap,R,S,D", [(64, 4, 16, 3), (256, 8, 8, 11), (128, 16, 8, 1)])
def test_marshal_matches_ref(cap, R, S, D):
    rng = np.random.default_rng(R * S)
    flat = jnp.array(rng.normal(size=(cap, D)), jnp.float32)
    counts = rng.multinomial(cap // 2, np.ones(R) / R)
    off = jnp.array(np.concatenate([[0], np.cumsum(counts)[:-1]]), jnp.int32)
    got = marshal_k.marshal(flat, off, num_ranks=R, slot=S, interpret=True)
    want = marshal_ref.marshal(flat, off, num_ranks=R, slot=S)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("cap,n,D", [(64, 64, 3), (256, 32, 9), (128, 200, 1)])
def test_gather_rows_matches_ref(cap, n, D):
    """The fused single-pass marshal (sort-permutation composed with the
    send-slot layout) against its jnp oracle, incl. out-of-range clamping."""
    rng = np.random.default_rng(cap + n)
    src = jnp.array(rng.integers(0, 2**32, (cap, D), dtype=np.uint32))
    idx = jnp.array(rng.integers(-3, cap + 3, n), jnp.int32)  # some out of range
    got = marshal_k.gather_rows(src, idx, interpret=True)
    want = marshal_ref.gather_rows(src, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_marshal_equals_sort_then_marshal():
    """fused_marshal(packed, perm[off[r]+s]) == marshal(packed[perm], off) —
    the single-pass path is bit-identical to the two-pass formulation."""
    cap, R, S, D = 64, 4, 8, 5
    rng = np.random.default_rng(11)
    packed = jnp.array(rng.integers(0, 2**32, (cap, D), dtype=np.uint32))
    perm = jnp.array(rng.permutation(cap), jnp.int32)
    counts = np.array([7, 0, 8, 5], np.int32)
    off = jnp.array(np.concatenate([[0], np.cumsum(counts)[:-1]]), jnp.int32)
    r_idx = jnp.repeat(jnp.arange(R, dtype=jnp.int32), S)
    s_idx = jnp.tile(jnp.arange(S, dtype=jnp.int32), R)
    src_rows = perm[jnp.clip(off[r_idx] + s_idx, 0, cap - 1)]
    got = marshal_ops.fused_marshal(packed, src_rows, num_ranks=R, slot=S)
    two_pass = marshal_k.marshal(
        jnp.take(packed, perm, axis=0), off, num_ranks=R, slot=S, interpret=True
    )
    for r in range(R):  # rows past the segment count are garbage in both
        np.testing.assert_array_equal(
            np.asarray(got[r][: counts[r]]), np.asarray(two_pass[r][: counts[r]])
        )


@pytest.mark.parametrize("cap,R,S,D", [(64, 4, 16, 3), (256, 8, 8, 5)])
def test_unmarshal_matches_ref(cap, R, S, D):
    rng = np.random.default_rng(cap + D)
    recv = jnp.array(rng.normal(size=(R, S, D)), jnp.float32)
    counts = jnp.array(rng.integers(0, S + 1, R), jnp.int32)
    off = jnp.cumsum(counts) - counts
    got = marshal_k.unmarshal(recv, off, counts, capacity=cap, interpret=True)
    want = marshal_ref.unmarshal(recv, off, counts, capacity=cap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_marshal_unmarshal_roundtrip_pytree():
    """marshal → unmarshal with the true counts reproduces the valid prefix."""
    @work_item
    @dataclasses.dataclass
    class W:
        x: jax.Array
        i: jax.Array

    cap, R, S = 64, 4, 16
    rng = np.random.default_rng(0)
    n = 40
    items = W(
        x=jnp.array(rng.normal(size=(cap, 3)), jnp.float32),
        i=jnp.arange(cap, dtype=jnp.int32),
    )
    counts = np.array([10, 0, 16, 5], np.int32)  # every segment fits the slot
    n = int(counts.sum())
    off = jnp.array(np.concatenate([[0], np.cumsum(counts)[:-1]]), jnp.int32)
    buf = marshal_ops.marshal_items(items, off, num_ranks=R, slot=S)
    back = marshal_ops.unmarshal_items(
        buf, off, jnp.array(counts), capacity=cap
    )
    np.testing.assert_array_equal(np.asarray(back.i[:n]), np.asarray(items.i[:n]))
    np.testing.assert_allclose(np.asarray(back.x[:n]), np.asarray(items.x[:n]))


# ------------------------------------------------------------- nbody_forces
@pytest.mark.parametrize("n,m,ti,tj", [(64, 64, 16, 16), (128, 256, 128, 128), (96, 32, 32, 32)])
def test_pairwise_accel_matches_ref(n, m, ti, tj):
    rng = np.random.default_rng(n + m)
    xi = jnp.array(rng.normal(size=(n, 3)), jnp.float32)
    xj = jnp.array(rng.normal(size=(m, 3)), jnp.float32)
    mj = jnp.array(rng.random(m), jnp.float32)
    got = nb_ops.K.pairwise_accel(xi, xj, mj, ti=ti, tj=tj, interpret=True)
    want = nb_ref.pairwise_accel(xi, xj, mj)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_pairwise_accel_zero_mass_padding_is_inert():
    xi = jnp.zeros((8, 3))
    xj = jnp.array(np.random.default_rng(1).normal(size=(16, 3)), jnp.float32)
    mj = jnp.zeros(16)
    got = nb_ops.pairwise_accel(xi, xj, mj)
    np.testing.assert_allclose(np.asarray(got), 0.0)


# -------------------------------------------------------------- rk4_advect
@pytest.mark.parametrize("field", [rk4_ops.ABC, rk4_ops.TORNADO, rk4_ops.TAYLOR_GREEN])
@pytest.mark.parametrize("n", [32, 1024, 96])
def test_rk4_matches_ref(field, n):
    rng = np.random.default_rng(field * 100 + n)
    pos = jnp.array(rng.normal(size=(n, 3)) * 2, jnp.float32)
    got_p, got_v = rk4_ops.rk4_step(pos, dt=0.05, field_id=field)
    want_p, want_v = rk4_ref.rk4_step(pos, dt=0.05, field_id=field)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want_p), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------- delta_tracking
@pytest.mark.parametrize("n,steps,g", [(64, 4, 4), (256, 8, 8), (128, 1, 2)])
def test_delta_tracking_matches_ref(n, steps, g):
    rng = np.random.default_rng(n + steps)
    o = jnp.array(rng.normal(size=(n, 3)), jnp.float32)
    d = jnp.array(rng.normal(size=(n, 3)), jnp.float32)
    d = d / jnp.linalg.norm(d, axis=-1, keepdims=True)
    t0 = jnp.zeros(n)
    texit = jnp.array(rng.random(n) * 4 + 0.5, jnp.float32)
    u = jnp.array(rng.random((n, steps, 2)), jnp.float32)
    blobs = jnp.array(
        np.concatenate(
            [rng.normal(size=(g, 3)), rng.random((g, 1)) + 0.3, rng.random((g, 1)) * 2],
            axis=1,
        ),
        jnp.float32,
    )
    got_t, got_s = dt_ops.track(o, d, t0, texit, u, blobs, majorant=4.0, steps=steps)
    want_t, want_s = dt_ref.track(o, d, t0, texit, u, blobs, majorant=4.0, steps=steps)
    np.testing.assert_allclose(np.asarray(got_t), np.asarray(want_t), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


def test_delta_tracking_statuses_are_consistent():
    n = 128
    rng = np.random.default_rng(0)
    o = jnp.zeros((n, 3))
    d = jnp.tile(jnp.array([[1.0, 0, 0]]), (n, 1))
    texit = jnp.full((n,), 0.01)  # everyone exits almost immediately
    u = jnp.array(rng.random((n, 4, 2)), jnp.float32)
    blobs = jnp.array([[0, 0, 0, 1.0, 0.0]], jnp.float32)  # zero density
    t, s = dt_ops.track(o, d, jnp.zeros(n), texit, u, blobs, majorant=1.0, steps=4)
    assert np.all(np.asarray(s) == dt_ref.EXITED)

"""Chaos-harness property tests (ISSUE 6): the lossless law, end to end.

Every test drives a deterministic fault-injection :class:`repro.chaos.Scenario`
through the REAL on-device drive loop (``RafiContext.run_until_done`` over the
configured exchange backend) and checks it against oracles that share no code
with the forwarding stack:

* retain mode delivers EXACTLY the schedule's per-destination checksums —
  zero drops, zero lost, clean termination — on flat and 2-/3-level routes;
* the flat retain *trajectory* (rounds to drain, per-burst retained rows,
  anti-starvation age) matches the numpy twin ``simulate_flat_retain``
  round for round;
* drop mode (the §3.3 oracle semantics) keeps the conservation identity
  ``emitted == delivered + resident + drops`` — every loss is counted,
  nothing vanishes silently;
* the measured ``age_max`` respects the ``spill_drain_model`` bound, so
  "bounded-delay anti-starvation" is a checked number, not a slogan.

Sizing note: the lossless law's precondition is that local capacity bounds
the resident population (see ``ForwardConfig.overflow``).  The flat cases
need only ``capacity=128``; hierarchical routes park mid-route backlog at
relay ranks, so they get ``capacity=256``.
"""
import numpy as np
import pytest

from repro import compat
from repro.chaos import (
    all_scenarios,
    convergecast,
    expected_by_rank,
    run_scenario,
    simulate_flat_retain,
)
from repro.roofline.analysis import spill_drain_model

pytestmark = pytest.mark.chaos

R = 8
S = 2          # starved per-peer send budget — every scenario spills
FLAT_CAP = 128
HIER_CAP = 256

SCENARIOS = {sc.name: sc for sc in all_scenarios(R)}
SCENARIO_IDS = sorted(SCENARIOS)


# ------------------------------------------------------------- flat retain
@pytest.mark.parametrize("marshal", ["sort", "scatter"])
@pytest.mark.parametrize("name", SCENARIO_IDS)
def test_flat_retain_matches_numpy_twin(mesh8, name, marshal):
    """Retain mode on the flat padded exchange is bit-exact with the numpy
    simulator: same deliveries, same number of rounds to drain, same total
    retained rows and same worst-case age — the whole trajectory, not just
    the end state."""
    sc = SCENARIOS[name]
    sim = simulate_flat_retain(sc, peer_capacity=S, capacity=FLAT_CAP)
    assert sim["done"] and sim["drops"] == 0  # the oracle itself is lossless
    res = run_scenario(
        mesh8, sc, capacity=FLAT_CAP, peer_capacity=S, overflow="retain",
        marshal=marshal, max_rounds=64,
    )
    np.testing.assert_array_equal(res["delivered"], expected_by_rank(sc))
    np.testing.assert_array_equal(res["delivered"], sim["delivered"])
    assert res["drops"] == 0 and res["lost"] == 0 and res["done"]
    assert res["resident"] == 0
    assert res["rounds"] == sim["rounds"]
    assert res["retained_rows"] == sim["retained_rows"]
    assert res["age_max"] == sim["age_max"]


@pytest.mark.pallas_interpret
def test_flat_retain_pallas_kernels(mesh8):
    """Retention over the Pallas kernel path (bucket-scatter marshal plan +
    scatter placement) agrees with the XLA path and the oracle on the
    worst-case convergecast."""
    sc = SCENARIOS["convergecast"]
    sim = simulate_flat_retain(sc, peer_capacity=S, capacity=FLAT_CAP)
    res = run_scenario(
        mesh8, sc, capacity=FLAT_CAP, peer_capacity=S, overflow="retain",
        marshal="scatter", use_pallas=True, max_rounds=64,
    )
    np.testing.assert_array_equal(res["delivered"], expected_by_rank(sc))
    assert res["drops"] == 0 and res["lost"] == 0 and res["done"]
    assert (res["rounds"], res["retained_rows"], res["age_max"]) == (
        sim["rounds"], sim["retained_rows"], sim["age_max"]
    )


def test_flat_retain_age_respects_drain_bound(mesh8):
    """Anti-starvation is BOUNDED delay: with FIFO retention the oldest row
    waits at most the time to drain the whole backlog through the clamp
    allowance, plus the emission span that keeps refilling it."""
    sc = SCENARIOS["convergecast"]
    res = run_scenario(
        mesh8, sc, capacity=FLAT_CAP, peer_capacity=S, overflow="retain",
        marshal="sort", max_rounds=64,
    )
    backlog = sc.rounds * sc.emits_per_round  # one sender's worst backlog
    bound = spill_drain_model(backlog, S)["age_bound"] + sc.rounds
    assert 0 < res["age_max"] <= bound, (res["age_max"], bound)


# ---------------------------------------------------- per-round trajectories
@pytest.mark.telemetry
@pytest.mark.parametrize("name", SCENARIO_IDS)
def test_flat_retain_trace_matches_twin_per_round(mesh8, name):
    """The full-window stats ring replays the burst round for round, not
    just in aggregate: the chronological retained-row and age-max traces
    equal the numpy twin's entry by entry, every forward of the burst is
    recorded (``rounds + 1`` entries — the initial forward plus one per body
    round), and the receiver-arrival trace accounts for every delivery."""
    sc = SCENARIOS[name]
    sim = simulate_flat_retain(sc, peer_capacity=S, capacity=FLAT_CAP)
    res = run_scenario(
        mesh8, sc, capacity=FLAT_CAP, peer_capacity=S, overflow="retain",
        max_rounds=64,
    )
    assert len(res["retained_trace"]) == res["rounds"] + 1
    np.testing.assert_array_equal(res["retained_trace"], sim["retained_trace"])
    np.testing.assert_array_equal(res["age_trace"], sim["age_trace"])
    assert int(np.sum(res["recv_trace"])) == res["delivered_total"]


# ----------------------------------------------------- hierarchical retain
HIER = [
    ("mesh_nodes24", ("node", "device"), (8, 8)),
    ("mesh_pods222", ("pod", "node", "device"), (8, 8, 8)),
]


@pytest.mark.parametrize("fixture,axes,caps", HIER, ids=["2level", "3level"])
@pytest.mark.parametrize("name", SCENARIO_IDS)
def test_hierarchical_retain_is_lossless(request, fixture, axes, caps, name):
    """On multi-tier routes a clamped row parks at the intermediate rank it
    reached and resumes next round — the schedule's checksums still arrive
    exactly, with zero drops, on every scenario."""
    mesh = request.getfixturevalue(fixture)
    sc = SCENARIOS[name]
    res = run_scenario(
        mesh, sc, capacity=HIER_CAP, axis_name=axes, exchange="hierarchical",
        level_capacities=caps, overflow="retain", marshal="sort",
        max_rounds=128,
    )
    np.testing.assert_array_equal(res["delivered"], expected_by_rank(sc))
    assert res["drops"] == 0 and res["lost"] == 0 and res["done"]
    assert res["resident"] == 0


@pytest.mark.parametrize("fixture,axes,caps", HIER, ids=["2level", "3level"])
def test_hierarchical_retain_scatter_marshal(request, fixture, axes, caps):
    """The sort-free scatter marshal preserves the lossless law on the
    worst-case convergecast too."""
    mesh = request.getfixturevalue(fixture)
    sc = SCENARIOS["convergecast"]
    res = run_scenario(
        mesh, sc, capacity=HIER_CAP, axis_name=axes, exchange="hierarchical",
        level_capacities=caps, overflow="retain", marshal="scatter",
        max_rounds=128,
    )
    np.testing.assert_array_equal(res["delivered"], expected_by_rank(sc))
    assert res["drops"] == 0 and res["lost"] == 0 and res["done"]


@pytest.mark.telemetry
@pytest.mark.parametrize("fixture,axes,caps", HIER, ids=["2level", "3level"])
def test_hierarchical_ring_telemetry_accounts_exactly(request, fixture, axes, caps):
    """Telemetry + retain on multi-tier routes: the ring's receiver-arrival
    trace sums to EXACTLY the delivered total (a row parked mid-route is
    retained, never double-counted as received), retention really fired and
    fully drained by the last forward, and the burst summary agrees with the
    chronological trace it was folded from."""
    mesh = request.getfixturevalue(fixture)
    sc = SCENARIOS["convergecast"]
    res = run_scenario(
        mesh, sc, capacity=HIER_CAP, axis_name=axes, exchange="hierarchical",
        level_capacities=caps, overflow="retain", max_rounds=128,
    )
    assert res["drops"] == 0 and res["lost"] == 0 and res["done"]
    assert len(res["recv_trace"]) == res["rounds"] + 1
    assert int(np.sum(res["recv_trace"])) == res["delivered_total"] == sc.emitted
    assert res["retained_trace"][-1] == 0  # drained clean
    assert int(np.sum(res["retained_trace"])) > 0  # the clamp really bit
    # summary (raw ring fold) vs trace (chronological view): one ring, two
    # independent reductions, same answer
    assert res["retained_rows"] == int(np.sum(res["retained_trace"]))
    assert res["age_max"] == int(np.max(res["age_trace"]))


# ------------------------------------------------------- drop conservation
@pytest.mark.parametrize("name", SCENARIO_IDS)
def test_drop_mode_conserves_padded(mesh8, name):
    """Drop mode under the same starved budgets: losses are allowed but
    every single one is COUNTED — delivered + resident + drops == emitted."""
    sc = SCENARIOS[name]
    res = run_scenario(
        mesh8, sc, capacity=FLAT_CAP, peer_capacity=S, overflow="drop",
        max_rounds=64,
    )
    assert res["lost"] == 0, res
    assert res["done"]


def test_drop_mode_conserves_onehot(mesh8):
    """The all-gather oracle backend has only a receiver clamp; starve the
    queue capacity instead and the identity must still balance."""
    sc = SCENARIOS["convergecast"]
    res = run_scenario(
        mesh8, sc, capacity=32, overflow="drop", exchange="onehot",
        max_rounds=64,
    )
    assert res["drops"] > 0  # the clamp really fired
    assert res["lost"] == 0, res


def test_drop_mode_conserves_hierarchical(mesh_nodes24):
    sc = SCENARIOS["convergecast"]
    res = run_scenario(
        mesh_nodes24, sc, capacity=FLAT_CAP, axis_name=("node", "device"),
        exchange="hierarchical", level_capacities=(2, 2), overflow="drop",
        max_rounds=64,
    )
    assert res["drops"] > 0
    assert res["lost"] == 0, res


def test_drop_mode_conserves_ragged(mesh8):
    if not compat.HAS_RAGGED_ALL_TO_ALL:
        pytest.skip("installed JAX has no lax.ragged_all_to_all")
    sc = SCENARIOS["convergecast"]
    res = run_scenario(
        mesh8, sc, capacity=FLAT_CAP, peer_capacity=S, overflow="drop",
        exchange="ragged", max_rounds=64,
    )
    assert res["lost"] == 0, res


def test_retain_beats_drop_where_it_matters(mesh8):
    """The headline contrast the benchmark gate codifies: on the convergecast
    with starved budgets, drop mode loses a large fraction of the traffic
    while retain mode loses nothing (it just takes more rounds)."""
    sc = convergecast(R)
    kw = dict(capacity=FLAT_CAP, peer_capacity=S, max_rounds=64)
    dropped = run_scenario(mesh8, sc, overflow="drop", **kw)
    retained = run_scenario(mesh8, sc, overflow="retain", **kw)
    assert dropped["drops"] > 0.2 * sc.emitted, dropped
    assert retained["drops"] == 0 and retained["lost"] == 0
    assert retained["delivered_total"] == sc.emitted
    assert retained["rounds"] > dropped["rounds"]  # the price: extra rounds


# ------------------------------------------------- pipelined (the overlap law)
@pytest.mark.pipeline
@pytest.mark.parametrize("marshal", ["sort", "scatter"])
@pytest.mark.parametrize("name", SCENARIO_IDS)
def test_flat_retain_pipelined_matches_numpy_twin(mesh8, name, marshal):
    """The overlap law under chaos: micro-shard pipelining
    (``pipeline_shards=2`` — the starved ``peer_capacity=2`` splits into
    1-row chunks, the worst case) keeps every scenario's retain trajectory
    bit-exact with the numpy twin — same deliveries, same rounds to drain,
    same retained rows, same worst-case age as the bulk round."""
    sc = SCENARIOS[name]
    sim = simulate_flat_retain(sc, peer_capacity=S, capacity=FLAT_CAP)
    res = run_scenario(
        mesh8, sc, capacity=FLAT_CAP, peer_capacity=S, overflow="retain",
        marshal=marshal, max_rounds=64, pipeline_shards=2,
    )
    np.testing.assert_array_equal(res["delivered"], expected_by_rank(sc))
    np.testing.assert_array_equal(res["delivered"], sim["delivered"])
    assert res["drops"] == 0 and res["lost"] == 0 and res["done"]
    assert res["resident"] == 0
    assert res["rounds"] == sim["rounds"]
    assert res["retained_rows"] == sim["retained_rows"]
    assert res["age_max"] == sim["age_max"]

"""End-to-end training substrate tests: convergence, checkpoint/restart
exactness, elastic resharding, integrity detection, serving."""
import dataclasses
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.data import SyntheticLM, make_batch_iterator
from repro.launch.mesh import make_test_mesh
from repro.launch.train import train
from repro.models.api import build_model
from repro.optim import AdamWConfig, adamw_init


def test_loss_decreases(tmp_path):
    _, _, losses = train(
        arch="qwen2-7b", smoke=True, steps=70, batch=8, seq=64,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=0, verbose=False,
        opt_cfg=AdamWConfig(lr=1e-2, warmup_steps=10, weight_decay=0.0),
    )
    first = np.mean([l for _, l in losses[:5]])
    last = np.mean([l for _, l in losses[-5:]])
    assert last < first * 0.9, f"loss did not decrease: {first} -> {last}"


def test_checkpoint_restart_is_exact(tmp_path):
    """Kill-and-resume reproduces the uninterrupted run bit-for-bit."""
    kw = dict(arch="qwen2-7b", smoke=True, batch=4, seq=64, verbose=False)
    d1 = str(tmp_path / "uninterrupted")
    _, _, losses_full = train(steps=20, ckpt_dir=d1, ckpt_every=100, **kw)

    d2 = str(tmp_path / "interrupted")
    train(steps=10, ckpt_dir=d2, ckpt_every=10, **kw)       # "crash" at 10
    assert latest_step(d2) == 10
    _, _, losses_resumed = train(steps=20, ckpt_dir=d2, ckpt_every=10, **kw)

    tail_full = dict(losses_full)[19]
    tail_resumed = dict(losses_resumed)[19]
    assert tail_full == tail_resumed, (
        f"resumed run diverged: {tail_full} != {tail_resumed}"
    )


def test_elastic_restore_different_mesh(tmp_path):
    """A checkpoint written under one mesh restores onto another layout."""
    from repro.launch.steps import build_train_step

    cfg = get_smoke_config("qwen2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, AdamWConfig())
    save_checkpoint(tmp_path / "ck", 5, {"params": params, "opt": opt})

    mesh2 = make_test_mesh(data=4, model=2)  # different factorization
    _, shardings = build_train_step(model, mesh2)
    restored = restore_checkpoint(
        tmp_path / "ck", 5, {"params": params, "opt": opt},
        shardings={"params": shardings["params"], "opt": shardings["opt"]},
    )
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the restored state is actually placed on the new mesh
    leaf = jax.tree.leaves(restored["params"])[0]
    assert leaf.sharding.mesh.shape["data"] == 4


def test_checkpoint_corruption_detected(tmp_path):
    cfg = get_smoke_config("qwen2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    path = save_checkpoint(tmp_path / "ck", 1, {"params": params})
    victim = sorted(path.glob("leaf_*.npy"))[0]
    raw = bytearray(victim.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(tmp_path / "ck", 1, {"params": params})


def test_checkpoint_retention(tmp_path):
    cfg = get_smoke_config("qwen2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    for s in range(5):
        save_checkpoint(tmp_path / "ck", s, {"p": params}, keep=2)
    steps = sorted(
        int(p.name.split("_")[1]) for p in (tmp_path / "ck").iterdir()
    )
    assert steps == [3, 4]


def test_data_pipeline_determinism_and_restart():
    ds = SyntheticLM(1000, 32, 8, seed=7)
    a = ds.batch_at(13)
    b = ds.batch_at(13)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    # iterator starting mid-stream matches direct indexing
    it = make_batch_iterator(ds, start_step=13)
    c = next(it)
    np.testing.assert_array_equal(a["tokens"], c["tokens"])


def test_host_sharded_loading_partitions_globally():
    full = SyntheticLM(1000, 16, 8, seed=3, process_index=0, process_count=1)
    p0 = SyntheticLM(1000, 16, 8, seed=3, process_index=0, process_count=2)
    p1 = SyntheticLM(1000, 16, 8, seed=3, process_index=1, process_count=2)
    assert p0.local_batch == 4 and p1.local_batch == 4
    # distinct slices (different rows)
    assert not np.array_equal(p0.batch_at(0)["tokens"], p1.batch_at(0)["tokens"])


def test_serve_engine_batched_requests():
    from repro.launch.serve import BatchedEngine, Request

    cfg = get_smoke_config("qwen2-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = BatchedEngine(model, params, slots=2, max_len=64)
    reqs = [
        Request(rid=i, prompt=np.arange(3 + i) % cfg.vocab_size, max_new_tokens=4)
        for i in range(5)
    ]
    out = eng.run(reqs)
    assert set(out) == {0, 1, 2, 3, 4}
    assert all(len(v) == 4 for v in out.values())
    # engine output is deterministic (greedy) — same prompt → same tokens
    out2 = BatchedEngine(model, params, slots=3, max_len=64).run(reqs)
    assert out == out2  # slot count must not change results


def test_gradient_compression_error_feedback():
    from repro.optim.grad_compress import compress_gradients, init_residuals

    g = {"w": jnp.array([1.0000001, -2.5, 3.1415926], jnp.float32)}
    res = init_residuals(g)
    total = jnp.zeros(3)
    for _ in range(64):
        q, res = compress_gradients(g, res)
        total = total + q["w"].astype(jnp.float32)
    # with error feedback the long-run average equals the true gradient
    np.testing.assert_allclose(np.asarray(total) / 64, np.asarray(g["w"]), rtol=1e-4)

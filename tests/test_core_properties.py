"""Hypothesis property tests for the forwarding core's invariants.

The jitted program is compiled ONCE (fixed shapes); hypothesis drives the
runtime data (destinations, counts, payload values), so each example is just
an execution.  Invariants:

  * conservation: when every capacity suffices, forwarding neither loses nor
    duplicates items — multiset of (value, dest) pairs is preserved, and
    every item lands on the rank it addressed;
  * accounting: sum(received) + drops == sum(emitted) in all cases;
  * termination total equals the global live count.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis — deterministic stub
    from _hypothesis_stub import given, settings, st
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.core import DISCARD, ForwardConfig, WorkQueue, forward_work, work_item

R, CAP = 8, 64


@work_item
@dataclasses.dataclass
class Item:
    val: jax.Array
    src: jax.Array


_PROTO_ITEMS = Item(
    val=jnp.zeros((R * CAP,), jnp.float32), src=jnp.zeros((R * CAP,), jnp.int32)
)


def _make_fn(mesh8, exchange):
    cfg = ForwardConfig("data", R, CAP, peer_capacity=CAP, exchange=exchange)

    def fwd(items_val, dest, counts):
        me = jax.lax.axis_index("data")
        q = WorkQueue(
            items=Item(val=items_val, src=me * jnp.ones(CAP, jnp.int32)),
            dest=dest,
            count=counts[0],
            drops=jnp.zeros((), jnp.int32),
        )
        nq, total = forward_work(q, cfg)
        return nq.items.val, nq.items.src, nq.count[None], nq.drops[None], total

    return jax.jit(
        compat.shard_map(
            fwd, mesh=mesh8,
            in_specs=(P("data"), P("data"), P("data")),
            out_specs=(P("data"), P("data"), P("data"), P("data"), P()),
        )
    )


@pytest.fixture(scope="module")
def fwd_padded(mesh8):
    return _make_fn(mesh8, "padded")


@given(
    data=st.data(),
)
@settings(max_examples=25, deadline=None)
def test_conservation_and_addressing(fwd_padded, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP // R, R).astype(np.int32)  # capacities suffice
    dest = np.full((R, CAP), DISCARD, np.int32)
    val = np.zeros((R, CAP), np.float32)
    sent = []
    for r in range(R):
        d = rng.integers(0, R, counts[r])
        v = rng.normal(size=counts[r]).astype(np.float32)
        dest[r, : counts[r]] = d
        val[r, : counts[r]] = v
        sent += [(round(float(x), 5), int(dd), r) for x, dd in zip(v, d)]

    out_val, out_src, out_counts, out_drops, total = fwd_padded(
        jnp.asarray(val).reshape(-1),
        jnp.asarray(dest).reshape(-1),
        jnp.asarray(np.repeat(counts, 1)),
    )
    out_val = np.asarray(out_val).reshape(R, CAP)
    out_src = np.asarray(out_src).reshape(R, CAP)
    out_counts = np.asarray(out_counts)
    got = []
    for r in range(R):
        n = out_counts[r]
        got += [
            (round(float(out_val[r, i]), 5), r, int(out_src[r, i])) for i in range(n)
        ]
    assert int(np.asarray(out_drops).sum()) == 0
    assert sorted(got) == sorted(sent), "items lost, duplicated, or misrouted"
    assert int(total) == len(sent)


@given(data=st.data())
@settings(max_examples=15, deadline=None)
def test_drop_accounting_balances(mesh8, data):
    """Even with pathological routing (everyone → rank 0), emitted ==
    received + dropped, globally."""
    fn = _make_fn(mesh8, "padded")
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    counts = rng.integers(0, CAP + 1, R).astype(np.int32)
    dest = np.zeros((R, CAP), np.int32)  # all to rank 0 — guaranteed overflow
    val = rng.normal(size=(R, CAP)).astype(np.float32)
    out_val, out_src, out_counts, out_drops, total = fn(
        jnp.asarray(val).reshape(-1),
        jnp.asarray(dest).reshape(-1),
        jnp.asarray(counts),
    )
    emitted = int(counts.sum())
    received = int(np.asarray(out_counts).sum())
    dropped = int(np.asarray(out_drops).sum())
    assert received + dropped == emitted
    assert int(total) == received

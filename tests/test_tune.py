"""Adaptive capacity controller (ISSUE 5): planning law + convergence.

Unit-tests the quantile → capacity solver against hand-built summaries, the
``ForwardConfig`` re-planning (flat ``peer_capacity`` and hierarchical
``level_capacities``), and the end-to-end property the subsystem exists for:
on a DRIFTING hot-spot workload (the hot destination rotates mid-run) a
deliberately undersized config converges, over a few bursts, to a VERIFIED
drop-free fixed point whose modeled padded wire bytes undercut the static
worst-case sizing — at every tier of a 3-level route.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import telemetry as TM
from repro.core import (
    DISCARD,
    ForwardConfig,
    enqueue,
    make_queue,
    run_until_done,
    work_item,
)
from repro.roofline.analysis import occupancy_waste_model, padded_wire_rows
from repro.tune import TunePolicy, autotune_forward, plan_capacities, solve_capacities

pytestmark = pytest.mark.telemetry

R = 8
AXES3 = ("pod", "node", "device")
BUCKETS = 8


# ------------------------------------------------------------- solver units
def _summary(hist_rows, dmax, caps):
    hist = np.asarray(hist_rows, np.int64)
    return {
        "tier_capacities": tuple(caps),
        "buckets": hist.shape[1],
        "demand_hist": hist,
        "demand_max": np.asarray(dmax, np.int64),
        "stage_drops": np.zeros(hist.shape[0], np.int64),
        "recv_drops": 0,
        "drops": 0,
        "rounds": 1,
        "window_filled": 1,
        "demand_total": hist.sum(axis=1),
        "sent_rows": hist.sum(axis=1),
        "recv_total_max": 0,
    }


def test_solver_quantile_one_uses_exact_max():
    s = _summary([[10, 2, 0, 0, 0, 0, 0, 1]], [37], caps=(16,))
    got = solve_capacities(s, (16,), TunePolicy(headroom=1.0, granularity=1, min_capacity=1))
    assert got == (37,)


def test_solver_headroom_and_granularity():
    s = _summary([[0, 0, 3, 0, 0, 0, 0, 0]], [20], caps=(64,))
    got = solve_capacities(
        s, (64,), TunePolicy(headroom=1.25, granularity=8, min_capacity=8)
    )
    assert got == (32,)  # ceil(20 * 1.25) = 25 → round up to 32


def test_solver_bounds_cap_the_headroom():
    """headroom must never push past the §6.3 provable worst case."""
    s = _summary([[0, 0, 0, 0, 0, 0, 0, 4]], [120], caps=(64,))
    pol = TunePolicy(headroom=1.5, granularity=8)
    assert solve_capacities(s, (64,), pol) == (184,)  # ceil(180)→184
    assert solve_capacities(s, (64,), pol, bounds=(128,)) == (128,)


def test_solver_keeps_capacity_without_observations():
    """No recorded segments (extent-1 tier / idle backend) ≠ zero demand."""
    s = _summary([[0] * 8, [5, 0, 0, 0, 0, 0, 0, 0]], [0, 3], caps=(32, 16))
    got = solve_capacities(
        s, (32, 16), TunePolicy(headroom=1.0, granularity=1, min_capacity=1)
    )
    assert got == (32, 3)


def test_solver_no_shrink_policy():
    s = _summary([[6, 0, 0, 0, 0, 0, 0, 0]], [2], caps=(64,))
    grow_only = TunePolicy(headroom=1.0, granularity=1, min_capacity=1, allow_shrink=False)
    assert solve_capacities(s, (64,), grow_only) == (64,)
    shrink = dataclasses.replace(grow_only, allow_shrink=True)
    assert solve_capacities(s, (64,), shrink) == (2,)


def test_plan_capacities_builds_valid_configs():
    flat = ForwardConfig("data", R, 64, exchange="padded", peer_capacity=4, telemetry=True)
    s = _summary([[0, 0, 0, 0, 0, 0, 0, 8]], [40], caps=(4,))
    planned = plan_capacities(s, flat, policy=TunePolicy(headroom=1.0, granularity=8))
    assert planned.peer_capacity == 40 and planned.telemetry
    hier = ForwardConfig(
        AXES3, R, 64, exchange="hierarchical", level_sizes=(2, 2, 2),
        level_capacities=(4, 4, 4), telemetry=True,
    )
    s3 = _summary(
        [[0] * 7 + [2], [0] * 7 + [2], [0] * 7 + [2]], [30, 20, 10], caps=(4, 4, 4)
    )
    planned3 = plan_capacities(s3, hier, policy=TunePolicy(headroom=1.0, granularity=8, min_capacity=8))
    assert planned3.level_capacities == (32, 24, 16)
    assert planned3.level_sizes == (2, 2, 2)
    with pytest.raises(ValueError, match="no per-peer segment capacities"):
        plan_capacities(s, ForwardConfig("data", R, 64, exchange="onehot", telemetry=True))


def test_occupancy_waste_model_populations_match():
    """wire_B and useful_B must cover the same population: summarize()'s
    sent_rows is summed over ranks AND rounds, so the model takes num_ranks
    and rounds and the waste fraction stays in [0, 1]."""
    item_b = 36
    # 8 ranks, 2 rounds, each rank ships 100 useful rows into 8×16 slots
    m = occupancy_waste_model(
        (8,), (16,), item_b,
        useful_rows=[8 * 2 * 100], rounds=2, num_ranks=8,
    )
    assert m["wire_B"] == 8 * 16 * 2 * 8 * item_b
    assert m["useful_B"] == 8 * 2 * 100 * item_b
    assert 0.0 <= m["waste_frac"] <= 1.0
    assert m["waste_frac"] == pytest.approx(1 - 100 / 128)
    # static single-rank single-round view unchanged
    assert occupancy_waste_model((8,), (16,), item_b)["wire_B"] == 128 * item_b


def test_autotune_requires_telemetry():
    cfg = ForwardConfig("data", R, 64, exchange="padded")
    with pytest.raises(ValueError, match="telemetry=True"):
        autotune_forward(lambda c: (None, None), cfg)


# ------------------------------------------- end-to-end drifting hot-spot
@work_item
@dataclasses.dataclass
class Unit:
    val: jax.Array


PROTO = Unit(val=jnp.zeros(()))
CAP, N_EMIT, ROUNDS = 1024, 96, 8


def _drift_emits(me, rnd, num_ranks):
    """Half of each rank's emits chase a rotating hot destination."""
    lane = jnp.arange(N_EMIT)
    hot = (rnd // 2) % num_ranks
    dest = jnp.where(lane % 2 == 0, hot, (me + lane) % num_ranks)
    return Unit(val=jnp.ones(N_EMIT)), dest.astype(jnp.int32)


def _make_run_burst(mesh, axes):
    def round_fn(q_in, acc, rnd):
        me = jax.lax.axis_index(axes)
        items, dest = _drift_emits(me, rnd + 1, R)
        out = make_queue(PROTO, CAP)
        out = enqueue(
            out, items, jnp.where(rnd + 1 < ROUNDS, dest, DISCARD),
            jnp.ones(N_EMIT, bool),
        )
        return out, acc

    @functools.lru_cache(maxsize=None)
    def compiled(cfg):
        def drive(_x):
            me = jax.lax.axis_index(axes)
            items, dest = _drift_emits(me, 0, R)
            q0 = enqueue(make_queue(PROTO, CAP), items, dest, jnp.ones(N_EMIT, bool))
            q, _acc, _rounds, _done, ring = run_until_done(
                round_fn, q0, jnp.zeros((), jnp.int32), cfg,
                max_rounds=ROUNDS + 2,
            )
            return q.drops[None], TM.stack_ring(ring)

        ring_spec = jax.tree.map(
            lambda _: P(axes),
            TM.make_ring(
                TM.num_tiers(cfg), window=cfg.telemetry_window,
                buckets=cfg.telemetry_buckets,
            ),
        )
        return jax.jit(
            compat.shard_map(
                drive, mesh=mesh, in_specs=P(axes),
                out_specs=(P(axes), ring_spec),
            )
        )

    def run_burst(cfg):
        drops, ring = compiled(cfg)(jnp.arange(8.0))
        return int(np.asarray(drops).sum()), ring

    return run_burst


def test_autotune_converges_drop_free_flat(mesh8):
    """Undersized flat config → converged, verified drop-free, and cheaper
    on the wire than the provable worst-case static sizing (peer slots of
    n_emit rows — every emit could share one destination)."""
    run_burst = _make_run_burst(mesh8, "data")
    cfg0 = ForwardConfig(
        "data", R, CAP, exchange="padded", peer_capacity=8,
        telemetry=True, telemetry_window=ROUNDS + 2, telemetry_buckets=BUCKETS,
    )
    bounds = (N_EMIT,)
    final, report = autotune_forward(
        run_burst, cfg0, policy=TunePolicy(headroom=1.25, granularity=8),
        bounds=bounds, max_bursts=6,
    )
    assert report.converged, [dataclasses.asdict(s) for s in report.steps]
    assert report.steps[0].drops > 0          # the cold start really dropped
    assert report.final_drops == 0
    # drop-free with strictly less wire than the worst-case static config
    tuned = occupancy_waste_model((R,), (final.peer_capacity,), 36)
    static = occupancy_waste_model((R,), bounds, 36)
    assert tuned["wire_B"] < static["wire_B"]
    # and the tuned capacity actually covers the recorded max demand
    assert final.peer_capacity >= report.steps[-1].demand_max[0]


def test_autotune_converges_drop_free_hierarchical(mesh_pods222):
    """The 3-level route: every tier's capacity is adapted; later tiers'
    demand only becomes visible once earlier clamps open (convergence takes
    >1 re-plan), and the tuned wire undercuts worst-case sizing per tier."""
    run_burst = _make_run_burst(mesh_pods222, AXES3)
    cfg0 = ForwardConfig(
        AXES3, R, CAP, exchange="hierarchical", level_sizes=(2, 2, 2),
        level_capacities=(8, 8, 8),
        telemetry=True, telemetry_window=ROUNDS + 2, telemetry_buckets=BUCKETS,
    )
    # §6.3 worst case per tier: a slot at tier l concatenates the emits of
    # prod(level_sizes[l+1:]) source sub-segments, each ≤ n_emit rows
    bounds = (4 * N_EMIT, 2 * N_EMIT, N_EMIT)
    final, report = autotune_forward(
        run_burst, cfg0, policy=TunePolicy(headroom=1.25, granularity=8),
        bounds=bounds, max_bursts=8,
    )
    assert report.converged, [dataclasses.asdict(s) for s in report.steps]
    assert report.steps[0].drops > 0
    assert report.final_drops == 0
    assert report.bursts > 2  # staged clamps reveal demand over bursts
    assert all(
        c <= b for c, b in zip(final.level_capacities, bounds)
    ), (final.level_capacities, bounds)
    tuned = occupancy_waste_model((2, 2, 2), final.level_capacities, 36)
    static = occupancy_waste_model((2, 2, 2), bounds, 36)
    assert tuned["wire_B"] < static["wire_B"]
    assert padded_wire_rows((2, 2, 2), final.level_capacities) == [
        2 * c for c in final.level_capacities
    ]

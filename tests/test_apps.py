"""Integration tests for the five paper applications (§5).

The central claim under test is the paper's §5.1 statement that forwarding
"does not in any way change which rays are traced": every app must produce
R-invariant results (bitwise where the math allows it), and the §5.2 baseline
comparison must reproduce deep compositing's artifact mechanism.
"""
import numpy as np
import pytest

from repro import compat
from repro.apps import lander, nbody, schlieren, streamlines, vopat


@pytest.fixture(scope="module")
def mesh1():
    return compat.make_mesh((1,), ("data",))


# ---------------------------------------------------------------- VoPaT §5.1
class TestVopat:
    scene = vopat.VopatScene(width=16, height=16, spp=1, max_bounces=3)

    def test_r_invariance_bitwise(self, mesh1, mesh8):
        img1, s1 = vopat.render(mesh1, self.scene)
        img8, s8 = vopat.render(mesh8, self.scene)
        assert s1["drops"] == 0 and s8["drops"] == 0
        np.testing.assert_array_equal(img1, img8)

    def test_image_is_sane(self, mesh8):
        img, stats = vopat.render(mesh8, self.scene)
        assert np.isfinite(img).all()
        assert 0.0 <= img.min() and img.max() <= 1.0 + 1e-6
        assert img.std() > 0.01  # not a constant field
        assert stats["rounds"] < 512

    def test_spp_accumulation_close(self, mesh1, mesh8):
        scene = vopat.VopatScene(width=8, height=8, spp=4)
        i1, _ = vopat.render(mesh1, scene)
        i8, _ = vopat.render(mesh8, scene)
        np.testing.assert_allclose(i1, i8, atol=1e-6)

    def test_pallas_sort_path_matches(self, mesh8):
        img_x, _ = vopat.render(mesh8, self.scene, use_pallas=False)
        img_p, _ = vopat.render(mesh8, self.scene, use_pallas=True)
        np.testing.assert_array_equal(img_x, img_p)


# --------------------------------------------------------------- Lander §5.2
class TestLander:
    scene = lander.LanderScene(width=16, height=16, num_slabs=32, samples_per_slab=4)

    def test_forwarding_r_invariant(self, mesh1, mesh8):
        f1, _ = lander.render_forwarding(mesh1, self.scene)
        f8, _ = lander.render_forwarding(mesh8, self.scene)
        np.testing.assert_array_equal(f1, f8)

    def test_deep_compositing_agrees_when_fragments_suffice(self, mesh8):
        """num_slabs/R = 4 segments per rank ⇒ F=4 fragments lose nothing."""
        fwd, _ = lander.render_forwarding(mesh8, self.scene)
        dc, stats = lander.render_deep_compositing(mesh8, self.scene, max_fragments=4)
        assert stats["dropped_fragments"] == 0
        np.testing.assert_allclose(dc, fwd, atol=1e-5)

    def test_deep_compositing_artifacts_when_fragments_overflow(self, mesh8):
        """The §5.2 limitation: too few fragment slots ⇒ dropped fragments ⇒
        artifacts — while the forwarding renderer is unaffected."""
        fwd, _ = lander.render_forwarding(mesh8, self.scene)
        dc, stats = lander.render_deep_compositing(mesh8, self.scene, max_fragments=1)
        assert stats["dropped_fragments"] > 0
        assert np.abs(dc - fwd).max() > 1e-3


# ------------------------------------------------------------ Schlieren §5.3
class TestSchlieren:
    scene = schlieren.SchlierenScene(width=16, height=16, num_slabs=32, samples_per_slab=4)

    def test_r_invariance_bitwise(self, mesh1, mesh8):
        u1, v1, _ = schlieren.render(mesh1, self.scene)
        u8, v8, _ = schlieren.render(mesh8, self.scene)
        np.testing.assert_array_equal(u1, u8)
        np.testing.assert_array_equal(v1, v8)

    def test_knife_edges_differ(self, mesh8):
        u, v, _ = schlieren.render(mesh8, self.scene)
        assert np.abs(u - v).max() > 0.01


# ---------------------------------------------------------- Streamlines §5.4
class TestStreamlines:
    cfg = streamlines.StreamlineConfig(num_particles=16, max_steps=24, dt=0.15)

    def test_matches_single_device_oracle(self, mesh8):
        tr8, lengths, stats = streamlines.run(mesh8, self.cfg)
        orc = streamlines.oracle(self.cfg)
        f8, fo = np.isfinite(tr8), np.isfinite(orc)
        np.testing.assert_array_equal(f8, fo)
        m = f8 & fo
        # XLA:CPU may fuse the RK4 chain differently inside the forwarding
        # while_loop vs the standalone oracle — ulp-level divergence is
        # expected; R-invariance below stays bitwise (same program).
        np.testing.assert_allclose(tr8[m], orc[m], atol=5e-4)
        assert stats["drops"] == 0

    def test_r_invariance(self, mesh1, mesh8):
        tr1, _, _ = streamlines.run(mesh1, self.cfg)
        tr8, _, _ = streamlines.run(mesh8, self.cfg)
        f1, f8 = np.isfinite(tr1), np.isfinite(tr8)
        np.testing.assert_array_equal(f1, f8)
        np.testing.assert_array_equal(tr1[f1], tr8[f8])

    def test_all_fields_terminate(self, mesh8):
        from repro.kernels.rk4_advect import ops as rk4

        for fid in (rk4.TORNADO, rk4.TAYLOR_GREEN):
            cfg = streamlines.StreamlineConfig(
                num_particles=8, max_steps=16, dt=0.2, field_id=fid
            )
            tr, lengths, stats = streamlines.run(mesh8, cfg)
            assert stats["rounds"] <= cfg.max_steps + 2
            assert (lengths >= 1).all()


# ---------------------------------------------------------------- NBody §5.5
class TestNBody:
    cfg = nbody.NBodyConfig(num_particles=64, steps=3, dt=1e-3, theta=0.3)

    def test_single_rank_matches_direct_sum(self, mesh1):
        p1, v1, s1 = nbody.run(mesh1, self.cfg)
        po, vo = nbody.oracle(self.cfg)
        np.testing.assert_allclose(p1, po, atol=1e-5)
        assert s1["drops"] == 0

    def test_multi_rank_approximation_and_conservation(self, mesh8):
        p8, v8, s8 = nbody.run(mesh8, self.cfg)
        po, vo = nbody.oracle(self.cfg)
        # particle count conserved every step (distributed migration intact)
        assert s8["totals"] == [self.cfg.num_particles] * self.cfg.steps
        assert s8["drops"] == 0
        # Barnes-Hut with octant refinement: positions stay close to direct sum
        assert np.abs(p8 - po).max() < 1e-2
        assert np.isfinite(v8).all()

    def test_three_contexts_coexist(self):
        """Structural: the three Listing-2 item types are distinct pytrees."""
        from repro.core import item_nbytes

        assert item_nbytes(nbody._p_proto()) == 9 * 4 + 4 + 4  # pos+vel+force+mass+uid
        assert item_nbytes(nbody._vp_proto()) == 3 * 4 + 4 + 4 + 4
        assert item_nbytes(nbody._rq_proto()) == 4

"""Collective-budget regression tests (ISSUE 1 + ISSUE 2 acceptance).

One ``forward_work`` round must lower to exactly ONE payload-sized collective
and ONE count collective — the whole point of the packed wire format.  If a
refactor reintroduces per-leaf collectives (the old code issued one
all_to_all per pytree leaf) or splits the ragged control plane back into
chained count exchanges, these tests fail.

The hierarchical two-stage round is budgeted at exactly TWO payload + TWO
count collectives, with the single slow-axis payload collective (stage B)
carrying ALL bulk bytes that cross the inter-node fabric — verified from the
ops' replica groups (fast axis: groups inside one node; slow axis: one lane
across nodes).

The inventory comes from ``roofline.analysis.collective_ops`` over the
lowered StableHLO of a shard_map'ed round.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import ForwardConfig, enqueue, forward_work, make_queue
from repro.core import types as T
from repro.roofline.analysis import collective_ops, group_axis

from helpers import make_rays, ray_proto

R, CAP = 8, 64
WORDS = T.pack_spec(ray_proto()).total_words  # 9 for the 36-byte test ray


def _lower_one_round(mesh8, cfg):
    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index("data")
        q = enqueue(
            q, make_rays(10), ((me + jnp.arange(10)) % R).astype(jnp.int32),
            jnp.ones(10, bool),
        )
        nq, total = forward_work(q, cfg)
        return nq.count[None], total, nq.items.tmin

    return jax.jit(
        compat.shard_map(
            kernel, mesh=mesh8, in_specs=P("data"),
            out_specs=(P("data"), P(), P("data")),
        )
    ).lower(jnp.arange(8.0)).as_text()


def _payload_threshold(cfg):
    """Anything at least one peer-slot of packed rows is payload; the count
    exchange is R (or R×R) int32 — orders of magnitude smaller."""
    return cfg.peer_capacity * WORDS * 4


@pytest.mark.parametrize("use_pallas", [False, True], ids=["xla", "pallas"])
def test_padded_round_has_one_payload_and_one_count_collective(mesh8, use_pallas):
    cfg = ForwardConfig("data", R, CAP, exchange="padded", use_pallas=use_pallas)
    ops = collective_ops(_lower_one_round(mesh8, cfg))
    a2a = [b for k, b in ops if k == "all-to-all"]
    payload = [b for b in a2a if b >= _payload_threshold(cfg)]
    counts = [b for b in a2a if b < _payload_threshold(cfg)]
    assert len(payload) == 1, f"want ONE payload all_to_all, got {a2a}"
    # the one payload collective carries the whole packed send buffer
    assert payload[0] == R * cfg.peer_capacity * WORDS * 4
    assert len(counts) == 1, f"want ONE count all_to_all, got {a2a}"
    assert counts[0] == R * 4
    # no stray payload movement on other collectives (psum of the scalar
    # count is the only other traffic)
    others = [(k, b) for k, b in ops if k != "all-to-all"]
    assert all(b <= R * R * 4 for _k, b in others), others


def test_ragged_round_has_one_payload_and_one_count_collective(mesh8):
    if not compat.HAS_RAGGED_ALL_TO_ALL:
        pytest.skip("installed JAX has no lax.ragged_all_to_all")
    cfg = ForwardConfig("data", R, CAP, exchange="ragged")
    ops = collective_ops(_lower_one_round(mesh8, cfg))
    ragged = [b for k, b in ops if k == "ragged-all-to-all"]
    assert len(ragged) == 1, f"want ONE ragged_all_to_all, got {ops}"
    # control plane: exactly one all_gather of the (R,) count vector —
    # NOT the three chained count all_to_alls of the naive Alltoallv plan
    assert sum(1 for k, _ in ops if k == "all-to-all") == 0, ops
    gathers = [b for k, b in ops if k == "all-gather"]
    assert gathers == [R * R * 4], ops


def _lower_hier_round(mesh, cfg):
    axes = cfg.axis_name

    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index(axes)
        q = enqueue(
            q, make_rays(10), ((me + jnp.arange(10)) % R).astype(jnp.int32),
            jnp.ones(10, bool),
        )
        nq, total = forward_work(q, cfg)
        return nq.count[None], total, nq.items.tmin

    return jax.jit(
        compat.shard_map(
            kernel, mesh=mesh, in_specs=P(axes),
            out_specs=(P(axes), P(), P(axes)),
        )
    ).lower(jnp.arange(8.0)).as_text()


@pytest.mark.parametrize("use_pallas", [False, True], ids=["xla", "pallas"])
def test_hierarchical_round_budget_two_payload_two_count(mesh_nodes24, use_pallas):
    """Two-stage budget guard: exactly 2 payload all_to_alls (one per mesh
    axis) + 2 tiny count all_to_alls, and ZERO payload collectives on the
    slow axis beyond stage B — all bulk inter-node bytes cross exactly once,
    padded per node."""
    F = 4
    cfg = ForwardConfig(
        ("node", "device"), R, CAP, exchange="hierarchical", fast_size=F,
        use_pallas=use_pallas,
    )
    ops = collective_ops(_lower_hier_round(mesh_nodes24, cfg), with_groups=True)
    a2a = [(b, group_axis(g, F)) for k, b, g in ops if k == "all-to-all"]
    threshold = min(cfg.peer_capacity, cfg.node_capacity) * WORDS * 4
    payload = [(b, ax) for b, ax in a2a if b >= threshold]
    counts = [(b, ax) for b, ax in a2a if b < threshold]
    assert len(payload) == 2, f"want TWO payload all_to_alls, got {a2a}"
    assert len(counts) == 2, f"want TWO count all_to_alls, got {a2a}"
    # stage A: the full (F, S_a, W) send buffer moves on the FAST axis only
    fast_payload = [b for b, ax in payload if ax == "fast"]
    assert fast_payload == [F * cfg.peer_capacity * WORDS * 4], payload
    # stage B: the ONE slow-axis payload collective carries the per-node
    # segments — (N, S_b, W), padded per node, never per rank
    N = R // F
    slow_payload = [b for b, ax in payload if ax == "slow"]
    assert slow_payload == [N * cfg.node_capacity * WORDS * 4], payload
    # nothing else ships payload-sized data across the slow fabric
    slow_bulk = [
        (k, b) for k, b, g in ops
        if b >= threshold and group_axis(g, F) in ("slow", "cross")
        and k != "all-to-all"
    ]
    assert slow_bulk == [], slow_bulk
    # control plane: one count exchange per axis
    assert sorted(ax for _b, ax in counts) == ["fast", "slow"], counts


def test_3level_round_budget_one_payload_one_count_per_axis(mesh_pods222):
    """N-level budget guard: on a (pod, node, device) mesh, exactly THREE
    payload all_to_alls (one per mesh axis, each a pure single-tier pattern)
    + three tiny count collectives, and no other payload-sized op touches a
    slower fabric."""
    from repro.roofline.analysis import group_tier

    sizes = (2, 2, 2)
    cfg = ForwardConfig(
        ("pod", "node", "device"), R, CAP, exchange="hierarchical",
        level_sizes=sizes,
    )
    txt = _lower_hier_round(mesh_pods222, cfg)
    ops = collective_ops(txt, with_groups=True)
    threshold = min(cfg.level_capacities) * WORDS * 4
    a2a = [(b, group_tier(g, sizes)) for k, b, g in ops if k == "all-to-all"]
    payload = [(b, t) for b, t in a2a if b >= threshold]
    counts = [(b, t) for b, t in a2a if b < threshold]
    assert len(payload) == 3, f"want THREE payload all_to_alls, got {a2a}"
    assert len(counts) == 3, f"want THREE count all_to_alls, got {a2a}"
    # one payload collective per tier, each of the padded per-segment size
    assert sorted(t for _b, t in payload) == [0, 1, 2], payload
    for b, t in payload:
        assert b == sizes[t] * cfg.level_capacities[t] * WORDS * 4, payload
    assert sorted(t for _b, t in counts) == [0, 1, 2], counts
    # nothing else ships payload-sized data across tier 0 or 1 (or mixed)
    stray = [
        (k, b) for k, b, g in ops
        if b >= threshold and group_tier(g, sizes) in (0, 1, "cross")
        and k != "all-to-all"
    ]
    assert stray == [], stray


def test_3level_extent1_axis_skips_its_stage():
    """An extent-1 tier must contribute NO collective at all — its stage is
    the identity, so a (2, 1, 4) mesh budgets like a 2-level route."""
    from repro.launch.mesh import make_pod_mesh
    from repro.roofline.analysis import group_tier

    sizes = (2, 1, 4)
    mesh = make_pod_mesh(*sizes)
    cfg = ForwardConfig(
        ("pod", "node", "device"), R, CAP, exchange="hierarchical",
        level_sizes=sizes,
    )
    txt = _lower_hier_round(mesh, cfg)
    ops = collective_ops(txt, with_groups=True)
    a2a = [(b, group_tier(g, sizes)) for k, b, g in ops if k == "all-to-all"]
    assert sorted({t for _b, t in a2a}) == [0, 2], a2a  # tier 1 never appears
    threshold = min(cfg.level_capacities[0], cfg.level_capacities[2]) * WORDS * 4
    assert sum(1 for b, _t in a2a if b >= threshold) == 2, a2a


def test_hierarchical_slow_axis_padding_is_per_node(mesh_nodes24):
    """The headline claim: slow-axis bytes are padded per NODE segment.  At
    EQUAL burst tolerance K (slot rows a single destination can absorb
    without drops), the flat padded exchange routed across nodes ships
    (R - F)·K padded rows over the slow fabric; hierarchical ships
    (N - 1)·K — exactly an R/N× reduction, since R - F = F·(N - 1).  The
    model must also agree with the lowered slow-axis accounting."""
    from repro.roofline.analysis import per_axis_collective_bytes, slow_axis_bytes_model

    F, N = 4, 2
    item_b = WORDS * 4
    K = 16  # any per-destination burst tolerance
    hier_model = slow_axis_bytes_model(
        "hierarchical", num_ranks=R, fast_size=F, item_bytes=item_b,
        node_capacity=K,
    )
    flat_model = slow_axis_bytes_model(
        "padded", num_ranks=R, fast_size=F, item_bytes=item_b,
        peer_capacity=K,
    )
    assert flat_model / hier_model == pytest.approx(R / N)
    # lowered HLO: stage B is the only slow-axis bulk and matches the model
    hier = ForwardConfig(("node", "device"), R, CAP, exchange="hierarchical", fast_size=F)
    txt = _lower_hier_round(mesh_nodes24, hier)
    per_axis = per_axis_collective_bytes(txt, F)
    assert per_axis["cross"] == 0
    slow_payload = N * hier.node_capacity * WORDS * 4
    assert per_axis["slow"] == slow_payload + N * 4  # stage B + its counts
    # the model counts only rows leaving the node: (N-1)/N of the collective
    assert slow_axis_bytes_model(
        "hierarchical", num_ranks=R, fast_size=F, item_bytes=item_b,
        node_capacity=hier.node_capacity,
    ) == slow_payload * (N - 1) / N


def test_flat_exchange_over_joint_axes_pays_cross_fabric_routing(mesh_nodes24):
    """Contrast guard: the flat padded exchange on the same 2-D mesh lowers
    to ONE all_to_all whose groups span nodes AND lanes — every byte of it is
    exposed to the slow fabric (the motivation for the two-stage route)."""
    cfg = ForwardConfig(("node", "device"), R, CAP, exchange="padded")
    ops = collective_ops(_lower_hier_round(mesh_nodes24, cfg), with_groups=True)
    payload = [
        (b, group_axis(g, 4)) for k, b, g in ops
        if k == "all-to-all" and b >= _payload_threshold(cfg)
    ]
    assert payload == [(R * cfg.peer_capacity * WORDS * 4, "cross")], payload


def _lower_round_with_telemetry(mesh, cfg, axes):
    """Like the other lowerings, but the kernel RETURNS the stats so the
    telemetry computation cannot be DCE'd out of the compared program."""
    from repro import telemetry as TM

    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index(axes)
        q = enqueue(
            q, make_rays(10), ((me + jnp.arange(10)) % R).astype(jnp.int32),
            jnp.ones(10, bool),
        )
        nq, total, stats = forward_work(q, cfg)
        return nq.count[None], total, nq.items.tmin, TM.stack_ring(stats)

    stats_spec = jax.tree.map(
        lambda _: P(axes),
        TM.make_stats(TM.num_tiers(cfg), cfg.telemetry_buckets),
    )
    return jax.jit(
        compat.shard_map(
            kernel, mesh=mesh, in_specs=P(axes),
            out_specs=(P(axes), P(), P(axes), stats_spec),
        )
    ).lower(jnp.arange(8.0)).as_text()


@pytest.mark.telemetry
@pytest.mark.parametrize(
    "fixture,axes,kw",
    [
        ("mesh8", "data", dict(exchange="padded")),
        ("mesh8", "data", dict(exchange="padded", marshal="scatter")),
        (
            "mesh_pods222", ("pod", "node", "device"),
            dict(exchange="hierarchical", level_sizes=(2, 2, 2)),
        ),
    ],
    ids=["padded", "padded-scatter", "hier3"],
)
def test_telemetry_adds_zero_collectives(request, fixture, axes, kw):
    """ISSUE 5 acceptance: stats capture is derived from control-plane values
    the round already computes — the FULL collective inventory (kind, bytes,
    replica groups) of a telemetry-on round is identical to the telemetry-off
    round.  Not just 'no extra payload collective': no extra collective of
    ANY size, so the per-axis budget law is untouched."""
    mesh = request.getfixturevalue(fixture)
    cfg_off = ForwardConfig(axes, R, CAP, **kw)
    cfg_on = ForwardConfig(axes, R, CAP, telemetry=True, **kw)
    lower_off = (
        _lower_one_round(mesh, cfg_off)
        if axes == "data"
        else _lower_hier_round(mesh, cfg_off)
    )
    ops_off = collective_ops(lower_off, with_groups=True)
    ops_on = collective_ops(
        _lower_round_with_telemetry(mesh, cfg_on, axes), with_groups=True
    )
    assert ops_on == ops_off, (ops_on, ops_off)


def _lower_round_any_overflow(mesh, cfg, axes):
    """Overflow-mode-agnostic lowering: a retain round returns the extra
    ``age_out`` (kept live so its computation can't be DCE'd); a drop round
    returns a zero placeholder so both programs have identical output
    signatures and only the round's internals differ."""
    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index(axes)
        q = enqueue(
            q, make_rays(10), ((me + jnp.arange(10)) % R).astype(jnp.int32),
            jnp.ones(10, bool),
        )
        res = forward_work(q, cfg)
        nq, total = res[0], res[1]
        age = res[2] if cfg.overflow == "retain" else jnp.zeros(CAP, jnp.int32)
        return nq.count[None], total, nq.items.tmin, age

    return jax.jit(
        compat.shard_map(
            kernel, mesh=mesh, in_specs=P(axes),
            out_specs=(P(axes), P(), P(axes), P(axes)),
        )
    ).lower(jnp.arange(8.0)).as_text()


@pytest.mark.chaos
@pytest.mark.parametrize(
    "fixture,axes,kw",
    [
        ("mesh8", "data", dict(exchange="padded")),
        ("mesh8", "data", dict(exchange="padded", marshal="scatter")),
        (
            "mesh_pods222", ("pod", "node", "device"),
            dict(exchange="hierarchical", level_sizes=(2, 2, 2)),
        ),
    ],
    ids=["padded", "padded-scatter", "hier3"],
)
def test_retain_adds_zero_collectives(request, fixture, axes, kw):
    """ISSUE 6 acceptance: retention is pure LOCAL compaction — the rows a
    clamp cuts never leave the rank, so the full collective inventory (kind,
    bytes, replica groups) of an ``overflow="retain"`` round is identical to
    the drop-mode round.  The budget, per-axis, and wire-format laws carry
    over to retain mode by construction, not by re-proof."""
    mesh = request.getfixturevalue(fixture)
    cfg_drop = ForwardConfig(axes, R, CAP, **kw)
    cfg_retain = ForwardConfig(axes, R, CAP, overflow="retain", **kw)
    ops_drop = collective_ops(
        _lower_round_any_overflow(mesh, cfg_drop, axes), with_groups=True
    )
    ops_retain = collective_ops(
        _lower_round_any_overflow(mesh, cfg_retain, axes), with_groups=True
    )
    assert ops_retain == ops_drop, (ops_retain, ops_drop)


def _lower_round_with_health(mesh, cfg, axes):
    """A forwarding round with a TRACED rank-health mask (replicated bool
    ``(R,)``) — the ISSUE 7 draining remap in the position the recovery
    drive uses it."""
    def kernel(_x, h):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index(axes)
        q = enqueue(
            q, make_rays(10), ((me + jnp.arange(10)) % R).astype(jnp.int32),
            jnp.ones(10, bool),
        )
        nq, total = forward_work(q, cfg, health=h)
        return nq.count[None], total, nq.items.tmin

    return jax.jit(
        compat.shard_map(
            kernel, mesh=mesh, in_specs=(P(axes), P()),
            out_specs=(P(axes), P(), P(axes)),
        )
    ).lower(jnp.arange(8.0), jnp.ones((R,), bool)).as_text()


@pytest.mark.recovery
@pytest.mark.parametrize(
    "fixture,axes,kw",
    [
        ("mesh8", "data", dict(exchange="padded")),
        ("mesh8", "data", dict(exchange="padded", marshal="scatter")),
        (
            "mesh_pods222", ("pod", "node", "device"),
            dict(exchange="hierarchical", level_sizes=(2, 2, 2)),
        ),
    ],
    ids=["padded", "padded-scatter", "hier3"],
)
def test_health_mask_adds_zero_collectives(request, fixture, axes, kw):
    """ISSUE 7 acceptance: the rank-draining destination remap is a pure
    LOCAL table lookup (``health_table`` + gather) applied before the
    marshal — the full collective inventory (kind, bytes, replica groups) of
    a health-masked round is identical to the plain round.  Draining a rank
    changes WHERE rows go, never what the fabric ships."""
    mesh = request.getfixturevalue(fixture)
    cfg = ForwardConfig(axes, R, CAP, **kw)
    lower_off = (
        _lower_one_round(mesh, cfg)
        if axes == "data"
        else _lower_hier_round(mesh, cfg)
    )
    ops_off = collective_ops(lower_off, with_groups=True)
    ops_health = collective_ops(
        _lower_round_with_health(mesh, cfg, axes), with_groups=True
    )
    assert ops_health == ops_off, (ops_health, ops_off)


@pytest.mark.recovery
def test_segmented_drive_preserves_collective_inventory(mesh8):
    """ISSUE 7 acceptance: splitting the drive into checkpointable start +
    segment programs re-arranges WHERE the while loop pauses, never what the
    fabric does — the combined collective inventory of the two programs
    equals the monolithic ``run_until_done`` drive's exactly (kind, bytes,
    replica groups), accounting counters and health remap included."""
    import numpy as np

    from repro.core import DISCARD, WorkQueue
    from repro.core.context import RafiContext

    ctx = RafiContext(
        mesh8, ray_proto(), capacity=CAP, peer_capacity=8, exchange="padded",
        overflow="retain", telemetry=True, telemetry_window=8,
    )

    def round_fn(q_in, acc, rnd):
        me = jax.lax.axis_index("data")
        out = make_queue(ray_proto(), CAP)
        out = enqueue(
            out, make_rays(4), ((me + rnd) % R) * jnp.ones(4, jnp.int32),
            (jnp.arange(4) >= 0) & (rnd < 2),
        )
        return out, acc + q_in.count

    spec = P("data")
    q0 = WorkQueue(
        items=jax.tree.map(
            lambda a: np.zeros((R * CAP,) + a.shape, a.dtype), ray_proto()
        ),
        dest=np.full((R * CAP,), DISCARD, np.int32),
        count=np.zeros((R,), np.int32),
        drops=np.zeros((R,), np.int32),
    )
    aux0 = np.zeros((R,), np.int32)
    health = np.ones((R,), bool)

    plain = ctx.run_until_done(round_fn, aux_specs=spec, max_rounds=16)
    ops_plain = collective_ops(
        plain.lower(q0, aux0).as_text(), with_groups=True
    )
    start_p, segment_p = ctx.checkpoint_drive_programs(
        round_fn, aux_specs=spec, accounting=True
    )
    ops_start = collective_ops(
        start_p.lower(q0, aux0, health).as_text(), with_groups=True
    )
    carry = start_p(q0, aux0, health)  # a concrete carry to lower against
    ops_segment = collective_ops(
        segment_p.lower(carry, np.int32(4), health).as_text(),
        with_groups=True,
    )
    assert sorted(ops_start + ops_segment) == sorted(ops_plain), (
        ops_start, ops_segment, ops_plain
    )


def test_cycle_hop_ships_one_packed_buffer(mesh8):
    """A ring hop moves items+dest as ONE packed collective_permute (plus the
    scalar count) — the cycling analogue of the forwarding budget."""
    from repro.core.cycling import cycle_step

    cfg = ForwardConfig("data", R, CAP, exchange="padded")

    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index("data")
        q = enqueue(
            q, make_rays(6), ((me + 1) % R) * jnp.ones(6, jnp.int32),
            jnp.ones(6, bool),
        )
        absorbed = make_queue(ray_proto(), CAP)
        nq, na = cycle_step(q, absorbed, cfg)
        return nq.count[None], na.count[None], nq.items.tmin

    txt = jax.jit(
        compat.shard_map(
            kernel, mesh=mesh8, in_specs=P("data"),
            out_specs=(P("data"), P("data"), P("data")),
        )
    ).lower(jnp.arange(8.0)).as_text()
    ops = collective_ops(txt)
    perms = [b for k, b in ops if k == "collective-permute"]
    # items (9 words) + dest (1 word) packed together → (CAP, 10) u32
    payload = [b for b in perms if b >= CAP * 4]
    assert payload == [CAP * (WORDS + 1) * 4], ops


# ----------------------------------------------- pipelined budget (ISSUE 8)
@pytest.mark.pipeline
@pytest.mark.parametrize("S", [2, 4])
def test_pipelined_padded_round_budget_S_payload_S_count(mesh8, S):
    """The overlap law's budget: ``pipeline_shards=S`` lowers to exactly S
    payload all_to_alls (one peer-chunk each) + S count all_to_alls — and
    the S chunks sum to the bulk round's wire bytes exactly (pipelining
    re-times the traffic, it never adds any)."""
    cfg = ForwardConfig("data", R, CAP, exchange="padded", pipeline_shards=S)
    ops = collective_ops(_lower_one_round(mesh8, cfg))
    a2a = [b for k, b in ops if k == "all-to-all"]
    chunk = cfg.peer_capacity // S
    payload = [b for b in a2a if b >= chunk * WORDS * 4]
    counts = [b for b in a2a if b < chunk * WORDS * 4]
    assert payload == [R * chunk * WORDS * 4] * S, f"S={S}: {a2a}"
    assert counts == [R * 4] * S, f"S={S}: {a2a}"
    assert sum(payload) == R * cfg.peer_capacity * WORDS * 4  # bytes conserved


@pytest.mark.pipeline
def test_pipelined_3level_budget_S_per_axis(mesh_pods222):
    """Per-axis overlap budget: on the (pod, node, device) route with
    ``pipeline_shards=2``, EVERY tier lowers to 2 chunk-sized payload
    all_to_alls + 2 count all_to_alls — the micro-shards pipeline each
    fabric independently, and no tier escapes its chunking."""
    from repro.roofline.analysis import group_tier

    sizes = (2, 2, 2)
    S = 2
    cfg = ForwardConfig(
        ("pod", "node", "device"), R, CAP, exchange="hierarchical",
        level_sizes=sizes, pipeline_shards=S,
    )
    ops = collective_ops(_lower_hier_round(mesh_pods222, cfg), with_groups=True)
    threshold = min(c // S for c in cfg.level_capacities) * WORDS * 4
    a2a = [(b, group_tier(g, sizes)) for k, b, g in ops if k == "all-to-all"]
    payload = [(b, t) for b, t in a2a if b >= threshold]
    counts = [(b, t) for b, t in a2a if b < threshold]
    assert sorted(t for _b, t in payload) == [0, 0, 1, 1, 2, 2], a2a
    for b, t in payload:
        assert b == sizes[t] * (cfg.level_capacities[t] // S) * WORDS * 4, (
            payload
        )
    assert sorted(t for _b, t in counts) == [0, 0, 1, 1, 2, 2], a2a


# ----------------------------------------------- credit budget (ISSUE 9)
def _lower_round_any_flow(mesh, cfg, axes):
    """Flow-mode-agnostic lowering: a credit round returns ``age_out`` and
    ``credits_out`` (kept live so their computation can't be DCE'd); other
    modes return zero placeholders so every program has the same output
    signature and only the round's internals differ."""
    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index(axes)
        q = enqueue(
            q, make_rays(10), ((me + jnp.arange(10)) % R).astype(jnp.int32),
            jnp.ones(10, bool),
        )
        credits = (
            jnp.full((R,), 4, jnp.int32) if cfg.flow == "credit" else None
        )
        res = forward_work(q, cfg, credits=credits)
        nq, total = res[0], res[1]
        age = res[2] if cfg.overflow == "retain" else jnp.zeros(CAP, jnp.int32)
        creds = res[3] if cfg.flow == "credit" else jnp.zeros(R, jnp.int32)
        return nq.count[None], total, nq.items.tmin, age, creds[None]

    spec = P(axes)
    return jax.jit(
        compat.shard_map(
            kernel, mesh=mesh, in_specs=spec,
            out_specs=(spec, P(), spec, spec, spec),
        )
    ).lower(jnp.arange(8.0)).as_text()


@pytest.mark.backpressure
def test_credit_round_budget_one_payload_one_widened_count(mesh8):
    """ISSUE 9 acceptance, flat: the credit round still lowers to exactly
    ONE payload all_to_all of the SAME size as the open round — the advert
    rides the count collective, widened from (R,) to (R, 2) i32.  Nothing
    payload-sized is added for flow control."""
    cfg = ForwardConfig(
        "data", R, CAP, exchange="padded", overflow="retain", flow="credit"
    )
    ops = collective_ops(_lower_round_any_flow(mesh8, cfg, "data"))
    a2a = [b for k, b in ops if k == "all-to-all"]
    payload = [b for b in a2a if b >= _payload_threshold(cfg)]
    counts = [b for b in a2a if b < _payload_threshold(cfg)]
    assert payload == [R * cfg.peer_capacity * WORDS * 4], a2a
    assert counts == [R * 2 * 4], a2a  # (R, 2) i32: count + advert columns


@pytest.mark.backpressure
@pytest.mark.parametrize(
    "fixture,axes,kw",
    [
        ("mesh8", "data", dict(exchange="padded")),
        ("mesh8", "data", dict(exchange="padded", marshal="scatter")),
        (
            "mesh_pods222", ("pod", "node", "device"),
            dict(exchange="hierarchical", level_sizes=(2, 2, 2),
                 level_capacities=(8, 8, 8)),
        ),
    ],
    ids=["padded", "padded-scatter", "hier3"],
)
def test_credit_adds_only_the_widened_count_column(request, fixture, axes, kw):
    """ISSUE 9 acceptance: the FULL collective inventory of a credit round
    equals the open-retain round's except that each per-tier count
    all_to_all grows by exactly one i32 column (A_l · 4 bytes — the advert
    lane).  Same op kinds, same op count, payload bytes untouched."""
    mesh = request.getfixturevalue(fixture)
    cfg_open = ForwardConfig(axes, R, CAP, overflow="retain", **kw)
    cfg_cred = ForwardConfig(
        axes, R, CAP, overflow="retain", flow="credit", **kw
    )
    ops_open = collective_ops(_lower_round_any_flow(mesh, cfg_open, axes))
    ops_cred = collective_ops(_lower_round_any_flow(mesh, cfg_cred, axes))
    assert len(ops_cred) == len(ops_open), (ops_cred, ops_open)
    sizes = kw.get("level_sizes", (R,))
    threshold = 4 * R * len(sizes) * 4  # any count block is far below this
    widened = 0
    for (ko, bo), (kc, bc) in zip(sorted(ops_open), sorted(ops_cred)):
        assert kc == ko
        if bc == bo:
            continue
        # a widened count exchange: one extra i32 per segment of the block
        assert ko == "all-to-all" and bo < threshold, (ops_open, ops_cred)
        assert (bc - bo) in {4 * a for a in sizes}, (bo, bc)
        widened += 1
    assert widened == len(sizes)  # one widened count collective per tier


# ----------------------------------------------- obs budget (ISSUE 10)
_OBS_CASES = {
    "padded": ("mesh8", dict(exchange="padded")),
    "onehot": ("mesh8", dict(exchange="onehot")),
    "hier3": (
        "mesh_pods222", dict(exchange="hierarchical", level_sizes=(2, 2, 2),
                             level_capacities=(8, 8, 8)),
    ),
    "ragged": ("mesh8", dict(exchange="ragged")),
}


@pytest.mark.obs
@pytest.mark.parametrize("case", sorted(_OBS_CASES))
def test_tracing_leaves_lowering_bit_identical(request, case):
    """ISSUE 10 acceptance: the observation law is HOST-only — with the
    ambient tracer installed (the ``obs`` marker turns it on through the
    ``RAFI_TRACE`` env toggle, so this exercises the real activation path),
    the lowered program of a forwarding round is BYTE-identical to the
    untraced one on every backend, and in particular the full collective
    inventory (kind, bytes, replica groups) is bit-identical.  Tracing can
    never change what the fabric ships — zero collective cost by proof, not
    by promise."""
    from repro.obs import trace as OT

    fixture, kw = _OBS_CASES[case]
    if case == "ragged" and not compat.HAS_RAGGED_ALL_TO_ALL:
        pytest.skip("installed JAX has no lax.ragged_all_to_all")
    mesh = request.getfixturevalue(fixture)
    axes = "data" if fixture == "mesh8" else ("pod", "node", "device")
    cfg = ForwardConfig(axes, R, CAP, **kw)
    lower = _lower_one_round if fixture == "mesh8" else _lower_hier_round
    assert OT.enabled(), "RAFI_TRACE toggle did not install the tracer"
    on = lower(mesh, cfg)
    OT.uninstall()
    off = lower(mesh, cfg)
    assert on == off, f"{case}: tracing changed the lowered StableHLO"
    assert collective_ops(on, with_groups=True) == collective_ops(
        off, with_groups=True
    )


@pytest.mark.obs
def test_traced_metered_drive_leaves_lowering_bit_identical(mesh8):
    """The full-stack version of the guard: the complete ``run_until_done``
    drive (telemetry on, so the metrics source rides the carry) lowers
    byte-identically with the tracer installed vs not — the span hooks live
    in the host wrapper, never inside the jitted program, and the metrics
    snapshot is derived post-hoc from host-surfaced values."""
    import numpy as np

    from repro.core import DISCARD, WorkQueue
    from repro.core.context import RafiContext
    from repro.obs import trace as OT

    def lower_drive():
        ctx = RafiContext(
            mesh8, ray_proto(), capacity=CAP, peer_capacity=8,
            exchange="padded", telemetry=True, telemetry_window=8,
        )

        def round_fn(q_in, acc, rnd):
            me = jax.lax.axis_index("data")
            out = make_queue(ray_proto(), CAP)
            out = enqueue(
                out, make_rays(4), ((me + rnd) % R) * jnp.ones(4, jnp.int32),
                (jnp.arange(4) >= 0) & (rnd < 2),
            )
            return out, acc + q_in.count

        q0 = WorkQueue(
            items=jax.tree.map(
                lambda a: np.zeros((R * CAP,) + a.shape, a.dtype), ray_proto()
            ),
            dest=np.full((R * CAP,), DISCARD, np.int32),
            count=np.zeros((R,), np.int32),
            drops=np.zeros((R,), np.int32),
        )
        aux0 = np.zeros((R,), np.int32)
        drive = ctx.run_until_done(
            round_fn, aux_specs=P("data"), max_rounds=16
        )
        return drive.lower(q0, aux0).as_text()

    assert OT.enabled()
    on = lower_drive()
    OT.uninstall()
    off = lower_drive()
    assert on == off, "tracing changed the lowered drive program"
    assert collective_ops(on, with_groups=True) == collective_ops(
        off, with_groups=True
    )


# The pre-refactor (PR 7) lowered HLO of one forward round, snapshotted with
# THIS harness's kernel before exchange.py was rebuilt on the stage graph.
# ``pipeline_shards=1`` must reproduce it byte for byte — the stage-graph
# refactor and the bulk fast path are provably the same program.  The ragged
# backend has no golden: this container's JAX predates ragged_all_to_all, so
# the pre-refactor code never lowered it here (its S=1 path is covered by
# test_ragged_round_has_one_payload_and_one_count_collective when present).
_PRE_REFACTOR_SHA256 = {
    "padded_sort": "f16365d26b599b27bd1a166d74fceaa5f90259332998d16b71d72d4439220717",
    "padded_scatter": "0d857013e3f21a9a541a26394f81fe9a9f31733f99428977d1bfe7e98e732f79",
    "padded_retain": "a8689e0fbf084f193636618b2566b1292aa82c9aa3f6e03f9423b91f70ae5b9d",
    "padded_telemetry": "f16365d26b599b27bd1a166d74fceaa5f90259332998d16b71d72d4439220717",
    "onehot": "fac130fe7f8774f30b03413382c9a995a8ebf2c949fa1e0c940acbde1297f660",
    "hier3_sort": "cadd1301d5b03a763651c7898ffd6867eca0578c85f8a96bf1ab323cf918ef55",
    "hier3_scatter": "e7598ae0e9d686f722ce48b9d3646a15ca4b2099cf81aa153c4bfc8f9bf81fe3",
    "hier3_retain": "b643d76cf02f463482cba167be465431a026df7d38c4354bceaeb4bda891431d",
}

_GOLDEN_CASES = {
    "padded_sort": ("mesh8", dict(exchange="padded")),
    "padded_scatter": ("mesh8", dict(exchange="padded", marshal="scatter")),
    "padded_retain": ("mesh8", dict(exchange="padded", overflow="retain")),
    "padded_telemetry": ("mesh8", dict(exchange="padded", telemetry=True)),
    "onehot": ("mesh8", dict(exchange="onehot")),
    "hier3_sort": (
        "mesh_pods222", dict(exchange="hierarchical", level_sizes=(2, 2, 2),
                             level_capacities=(8, 8, 8)),
    ),
    "hier3_scatter": (
        "mesh_pods222", dict(exchange="hierarchical", level_sizes=(2, 2, 2),
                             level_capacities=(8, 8, 8), marshal="scatter"),
    ),
    "hier3_retain": (
        "mesh_pods222", dict(exchange="hierarchical", level_sizes=(2, 2, 2),
                             level_capacities=(8, 8, 8), overflow="retain"),
    ),
}


def _lower_golden(mesh, cfg):
    """The snapshot harness: arity-agnostic (retain/telemetry rounds return
    more, the extras stay unused exactly as in the golden lowering)."""
    axes = cfg.axis_name

    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index(axes)
        q = enqueue(
            q, make_rays(10), ((me + jnp.arange(10)) % R).astype(jnp.int32),
            jnp.ones(10, bool),
        )
        res = forward_work(q, cfg)
        nq, total = res[0], res[1]
        return nq.count[None], total, nq.items.tmin

    spec = P(axes)
    return jax.jit(
        compat.shard_map(
            kernel, mesh=mesh, in_specs=spec, out_specs=(spec, P(), spec)
        )
    ).lower(jnp.arange(8.0)).as_text()


@pytest.mark.pipeline
@pytest.mark.skipif(
    jax.__version__ != "0.4.37",
    reason="golden HLO digests are pinned to the container's JAX lowering",
)
@pytest.mark.parametrize("case", sorted(_GOLDEN_CASES))
def test_bulk_lowering_bitidentical_to_pre_refactor(request, case):
    """ISSUE 8 acceptance: with ``pipeline_shards=1`` the stage-graph
    exchange lowers BYTE-identically to the pre-refactor monolith — same
    StableHLO text, so same compiled program, no trust required."""
    import hashlib

    fixture, kw = _GOLDEN_CASES[case]
    mesh = request.getfixturevalue(fixture)
    axes = "data" if fixture == "mesh8" else ("pod", "node", "device")
    cfg = ForwardConfig(axes, R, CAP, pipeline_shards=1, **kw)
    got = hashlib.sha256(_lower_golden(mesh, cfg).encode()).hexdigest()
    assert got == _PRE_REFACTOR_SHA256[case], (
        f"{case}: S=1 lowering diverged from the pre-refactor HLO"
    )

"""Collective-budget regression tests (ISSUE 1 acceptance).

One ``forward_work`` round must lower to exactly ONE payload-sized collective
and ONE count collective — the whole point of the packed wire format.  If a
refactor reintroduces per-leaf collectives (the old code issued one
all_to_all per pytree leaf) or splits the ragged control plane back into
chained count exchanges, these tests fail.

The inventory comes from ``roofline.analysis.collective_ops`` over the
lowered StableHLO of a shard_map'ed round.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import ForwardConfig, enqueue, forward_work, make_queue
from repro.core import types as T
from repro.roofline.analysis import collective_ops

from helpers import make_rays, ray_proto

R, CAP = 8, 64
WORDS = T.pack_spec(ray_proto()).total_words  # 9 for the 36-byte test ray


def _lower_one_round(mesh8, cfg):
    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index("data")
        q = enqueue(
            q, make_rays(10), ((me + jnp.arange(10)) % R).astype(jnp.int32),
            jnp.ones(10, bool),
        )
        nq, total = forward_work(q, cfg)
        return nq.count[None], total, nq.items.tmin

    return jax.jit(
        compat.shard_map(
            kernel, mesh=mesh8, in_specs=P("data"),
            out_specs=(P("data"), P(), P("data")),
        )
    ).lower(jnp.arange(8.0)).as_text()


def _payload_threshold(cfg):
    """Anything at least one peer-slot of packed rows is payload; the count
    exchange is R (or R×R) int32 — orders of magnitude smaller."""
    return cfg.peer_capacity * WORDS * 4


@pytest.mark.parametrize("use_pallas", [False, True], ids=["xla", "pallas"])
def test_padded_round_has_one_payload_and_one_count_collective(mesh8, use_pallas):
    cfg = ForwardConfig("data", R, CAP, exchange="padded", use_pallas=use_pallas)
    ops = collective_ops(_lower_one_round(mesh8, cfg))
    a2a = [b for k, b in ops if k == "all-to-all"]
    payload = [b for b in a2a if b >= _payload_threshold(cfg)]
    counts = [b for b in a2a if b < _payload_threshold(cfg)]
    assert len(payload) == 1, f"want ONE payload all_to_all, got {a2a}"
    # the one payload collective carries the whole packed send buffer
    assert payload[0] == R * cfg.peer_capacity * WORDS * 4
    assert len(counts) == 1, f"want ONE count all_to_all, got {a2a}"
    assert counts[0] == R * 4
    # no stray payload movement on other collectives (psum of the scalar
    # count is the only other traffic)
    others = [(k, b) for k, b in ops if k != "all-to-all"]
    assert all(b <= R * R * 4 for _k, b in others), others


def test_ragged_round_has_one_payload_and_one_count_collective(mesh8):
    if not compat.HAS_RAGGED_ALL_TO_ALL:
        pytest.skip("installed JAX has no lax.ragged_all_to_all")
    cfg = ForwardConfig("data", R, CAP, exchange="ragged")
    ops = collective_ops(_lower_one_round(mesh8, cfg))
    ragged = [b for k, b in ops if k == "ragged-all-to-all"]
    assert len(ragged) == 1, f"want ONE ragged_all_to_all, got {ops}"
    # control plane: exactly one all_gather of the (R,) count vector —
    # NOT the three chained count all_to_alls of the naive Alltoallv plan
    assert sum(1 for k, _ in ops if k == "all-to-all") == 0, ops
    gathers = [b for k, b in ops if k == "all-gather"]
    assert gathers == [R * R * 4], ops


def test_cycle_hop_ships_one_packed_buffer(mesh8):
    """A ring hop moves items+dest as ONE packed collective_permute (plus the
    scalar count) — the cycling analogue of the forwarding budget."""
    from repro.core.cycling import cycle_step

    cfg = ForwardConfig("data", R, CAP, exchange="padded")

    def kernel(_x):
        q = make_queue(ray_proto(), CAP)
        me = jax.lax.axis_index("data")
        q = enqueue(
            q, make_rays(6), ((me + 1) % R) * jnp.ones(6, jnp.int32),
            jnp.ones(6, bool),
        )
        absorbed = make_queue(ray_proto(), CAP)
        nq, na = cycle_step(q, absorbed, cfg)
        return nq.count[None], na.count[None], nq.items.tmin

    txt = jax.jit(
        compat.shard_map(
            kernel, mesh=mesh8, in_specs=P("data"),
            out_specs=(P("data"), P("data"), P("data")),
        )
    ).lower(jnp.arange(8.0)).as_text()
    ops = collective_ops(txt)
    perms = [b for k, b in ops if k == "collective-permute"]
    # items (9 words) + dest (1 word) packed together → (CAP, 10) u32
    payload = [b for b in perms if b >= CAP * 4]
    assert payload == [CAP * (WORDS + 1) * 4], ops

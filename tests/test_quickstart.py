"""Tier-1 smoke test for ``examples/quickstart.py`` (ISSUE 10, satellite 4).

The quickstart is the repo's front door — every law gets one numbered
section, and the script asserts its own numbers (termination sum, bit-exact
pipelining, the backpressure goodput split, the flight report's verdict).
Here we only have to prove it RUNS: exit 0 and every section header printed,
in order, in a clean subprocess with the suite's own device settings (the
parent process may carry mutated XLA_FLAGS — e.g. the roofline inspector's
512-device CLI default — so the env is pinned explicitly)."""
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]

SECTIONS = [
    "== 1. work-item type",
    "== 2. per-rank round kernel",
    "== 3. drive to distributed termination",
    "== 4. telemetry summary",
    "== 5. pipelined overlap, bit-exact",
    "== 6. backpressure under sustained overload",
    "== 7. observation law: trace export + flight-data report",
]


@pytest.mark.slow
def test_quickstart_runs_clean():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / "quickstart.py")],
        cwd=str(REPO), env=env, capture_output=True, text=True, timeout=540,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    out = proc.stdout
    positions = [out.find(h) for h in SECTIONS]
    assert all(p >= 0 for p in positions), f"missing headers in:\n{out}"
    assert positions == sorted(positions), "sections out of order"
    # the script's own final verdict line
    assert out.rstrip().endswith("OK")
    # the analyzer flagged exactly the open-flow run
    assert "verdict: 1 degraded run(s) — sustained_overload_open" in out
